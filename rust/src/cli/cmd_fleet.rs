//! `capstore fleet [<net> [<org>]]` — deterministic fleet-scale
//! serving: shard one seeded request stream across N accelerator
//! instances under a dispatch policy, or (`--rank`) run the
//! fleet-level DSE that picks the design mix + policy off a Pareto
//! front.

use crate::dse::Explorer;
use crate::fleet::{
    simulate_fleet, DispatchPolicy, FleetSpec, InstanceReport,
};
use crate::report::Table;
use crate::scenario::{Evaluator, Scenario};
use crate::telemetry::CounterRegistry;
use crate::timeline::Timeline;
use crate::traffic::{rank_fleet, ServiceModel};
use crate::util::json::Json;
use crate::util::units::fmt_energy_uj;
use crate::{Error, Result};

use super::context::CommandContext;
use super::output::Output;
use super::spec::{self, FlagSpec};
use super::Command;

pub struct FleetCmd;

impl Command for FleetCmd {
    fn name(&self) -> &'static str {
        "fleet"
    }

    fn about(&self) -> &'static str {
        "fleet-scale serving across N instances, --rank fleet DSE"
    }

    fn groups(&self) -> &'static [&'static [FlagSpec]] {
        &[
            spec::SCENARIO,
            spec::MEMORY,
            spec::TIME_UNBATCHED,
            spec::TRAFFIC_ONE,
            spec::FLEET,
            spec::PROFILE_ONLY,
            spec::PREFLIGHT,
        ]
    }

    fn max_positionals(&self) -> usize {
        2
    }

    fn positional_usage(&self) -> &'static str {
        "[<net> [<org>]]"
    }

    fn long_help(&self) -> &'static str {
        "Shards the seeded serving simulation across --instances\n\
         accelerator instances: requests route per --policy\n\
         (round-robin spreads, jsq joins the shortest queue, packing\n\
         bin-packs onto the fewest warm instances so the unloaded tail\n\
         sleeps past its break-even point and whole accelerators gate\n\
         off).  --elastic starts at --min-active instances and grows/\n\
         shrinks the active set on queue depth; waking a parked\n\
         instance pays the cold premium.  Same seed in, byte-identical\n\
         report out — the fleet loop builds zero Timeline IRs.\n\
         \n\
         `--rank` is the fleet-level DSE: it sweeps the scenario's\n\
         (network, tech) pair, takes the Pareto front, and picks the\n\
         design mix (homogeneous fleets plus two-design prefix blends)\n\
         and dispatch policy that minimize SLO-feasible energy per\n\
         served inference, so it rejects any pinned design-point axis\n\
         the ranking would override."
    }

    fn run(&self, ctx: &CommandContext) -> Result<Output> {
        let sc = ctx.scenario_with_positionals()?;
        let ranking = ctx.flags.contains_key("rank");

        // `--rank` explores the organization/geometry/dma axes itself —
        // a pinned design point would be silently overridden by the
        // sweep, and this CLI rejects rather than ignores (mirroring
        // `capstore traffic --rates`).
        if ranking {
            if ctx.flags.contains_key("profile") {
                return Err(Error::Config(
                    "--profile reports the counters of one fleet run; \
                     --rank runs a whole ranking sweep — drop one"
                        .into(),
                ));
            }
            if ctx.positionals.get(1).is_some() {
                return Err(Error::Config(
                    "`fleet <net> <org> --rank` pins an organization \
                     the ranking sweeps over — drop the organization \
                     (the ranking tries every front point)"
                        .into(),
                ));
            }
            for pinned in ["org", "banks", "sectors", "dma", "dma-bw"] {
                if ctx.flags.contains_key(pinned) {
                    return Err(Error::Config(format!(
                        "`--rank` explores the organization/geometry/\
                         dma axes itself: --{pinned} would be silently \
                         overridden — drop it to rank, or drop --rank \
                         to simulate that single design point"
                    )));
                }
            }
            if let Some(doc) = ctx.config_doc() {
                for key in ["organization", "banks", "sectors"] {
                    if doc.get("memory", key).is_some() {
                        return Err(Error::Config(format!(
                            "`--rank` explores the organization/\
                             geometry axes itself: the --config file \
                             pins `[memory] {key}`, which the ranking \
                             would override — drop it, or drop --rank"
                        )));
                    }
                }
            }
            if ctx.scenario_doc().is_some() {
                let without = ctx.scenario_without_doc()?;
                if sc.organization != without.organization
                    || sc.geometry != without.geometry
                    || sc.dma != without.dma
                {
                    return Err(Error::Config(
                        "`--rank` explores the organization/geometry/\
                         dma axes itself: the scenario file pins \
                         values the ranking would override — drop \
                         those keys, or drop --rank"
                            .into(),
                    ));
                }
            }
        }

        // workload + batching resolve exactly like `capstore traffic`;
        // the fleet loop injects no faults, so a scenario carrying a
        // live [faults] section is rejected rather than ignored.
        let (profile, policy, faults, _resilience) =
            super::cmd_traffic::resolve_serving(ctx, &sc)?;
        if !faults.is_identity() {
            return Err(Error::Config(
                "the fleet simulator does not inject faults — drop the \
                 scenario's [faults] section (single-instance fault \
                 studies live in `capstore traffic`)"
                    .into(),
            ));
        }

        let fleet = resolve_fleet(ctx, &sc)?;

        // static pre-flight on the fully resolved workload + fleet
        // shape (flags already folded in, so the scenario doc's
        // key->location mapping no longer applies — pass no doc).  The
        // --rank path skips it: the ranking sweeps design axes the
        // single-scenario rules would mis-blame.
        if !ranking {
            let checked = Scenario {
                traffic: Some(profile.clone()),
                fleet: Some(fleet.clone()),
                ..sc.clone()
            };
            super::cmd_check::preflight(ctx, &checked, None)?;
        }

        let ev = Evaluator::new();
        if ranking {
            return run_rank(&ev, &sc, &profile, &policy, &fleet);
        }

        let profiling = ctx.flags.contains_key("profile");
        let builds_before = Timeline::build_count();
        let svc = ServiceModel::new(&ev, &sc, policy.max_batch)?;
        let models = vec![svc; fleet.instances];
        let report = simulate_fleet(&models, &profile, &policy, &fleet)?;

        let mut out = Output::new();
        out.json = report.to_json();

        out.text(format!(
            "scenario: {} x {} instances",
            sc.label(),
            fleet.instances
        ));
        out.text(format!("traffic:  {}", profile.label()));
        out.text(format!(
            "fleet:    policy {}{}",
            report.policy.label(),
            if fleet.elastic {
                format!(
                    ", elastic (min {} active, scale-up depth {})",
                    fleet.min_active, fleet.scale_up_depth
                )
            } else {
                String::new()
            },
        ));
        out.text(format!(
            "\narrivals {}  served {}  queued {}  shed {}  in {} \
             batches (mean occupancy {:.2})",
            report.arrivals,
            report.served,
            report.queued,
            report.shed,
            report.batches,
            report.mean_occupancy(),
        ));
        out.text(format!(
            "throughput {:.1} inf/s over a {:.3}s window",
            report.throughput_per_sec(),
            profile.duration_secs,
        ));
        if let Some(s) = &report.latency_ms {
            out.text(format!(
                "latency ms: p50 {:.3}  p95 {:.3}  p99 {:.3}  \
                 max {:.3}",
                s.median, s.p95, s.p99, s.max
            ));
        }
        out.text(format!(
            "SLO {} ms: {} violations ({:.2}% of served)",
            profile.slo_ms,
            report.slo_violations,
            100.0 * report.slo_violation_fraction(),
        ));
        out.text(format!(
            "starts: {} cold, {} warm; elastic: {} scale-ups, {} \
             scale-downs, peak {} active",
            report.cold_starts,
            report.warm_starts,
            report.scale_ups,
            report.scale_downs,
            report.peak_active,
        ));
        out.text(format!(
            "gated off whole: {} of {} instances slept past \
             break-even end to end",
            report.gated_off_instances, fleet.instances,
        ));
        out.text(format!(
            "energy: batches {} + idle {} - warm saving {} = {} \
             ({:.3} µJ/inference)",
            fmt_energy_uj(report.batch_pj),
            fmt_energy_uj(report.idle_pj),
            fmt_energy_uj(report.warm_saving_pj),
            fmt_energy_uj(report.total_pj()),
            report.energy_uj_per_inference(),
        ));
        out.blank();
        out.table(instance_table(
            &report.per_instance,
            report.horizon_cycles,
        ));

        if profiling {
            // deterministic counters: the fleet conservation buckets
            // and dispatch tallies of this run, plus how many Timeline
            // IRs the command built (service-model construction only —
            // the fleet loop itself builds zero)
            let mut counters =
                CounterRegistry::from_fleet_report(&report);
            counters.set(
                "timeline.builds",
                Timeline::build_count() - builds_before,
            );
            let snap = counters.snapshot();
            if let Json::Obj(m) = &mut out.json {
                m.insert(
                    "profile".into(),
                    Json::obj(vec![("counters", snap.to_json())]),
                );
            }
            out.blank();
            out.table(snap.table("profile — deterministic counters"));
        }
        Ok(out)
    }
}

/// Resolve the fleet shape: the scenario's `[fleet]` section (if any)
/// under the flags, with validation.
fn resolve_fleet(
    ctx: &CommandContext,
    sc: &Scenario,
) -> Result<FleetSpec> {
    let mut fleet = sc.fleet.clone().unwrap_or_default();
    if let Some(v) = ctx.parsed("instances")? {
        fleet.instances = v;
    }
    if let Some(v) = ctx.flag("policy") {
        fleet.policy = DispatchPolicy::by_name(v).ok_or_else(|| {
            Error::Config(format!(
                "--policy: want one of {}, got {v:?}",
                DispatchPolicy::names().join("|")
            ))
        })?;
    }
    if ctx.flags.contains_key("elastic") {
        fleet.elastic = true;
    }
    if let Some(v) = ctx.parsed("scale-up-depth")? {
        fleet.scale_up_depth = v;
    }
    if let Some(v) = ctx.parsed("min-active")? {
        fleet.min_active = v;
    }
    fleet.validate()?;
    Ok(fleet)
}

/// The per-instance decomposition table shared by both formats.
fn instance_table(
    instances: &[InstanceReport],
    horizon: u64,
) -> Table {
    let mut t = Table::new(
        "per-instance decomposition",
        &["inst", "design", "arrivals", "served", "queued", "batches",
          "occup", "cold", "warm", "µJ", "gated off"],
    );
    for (i, inst) in instances.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            inst.design_label.clone(),
            inst.arrivals.to_string(),
            inst.served.to_string(),
            inst.queued.to_string(),
            inst.batches.to_string(),
            format!("{:.2}", inst.occupancy(horizon)),
            inst.cold_starts.to_string(),
            inst.warm_starts.to_string(),
            format!("{:.1}", inst.total_pj() * 1.0e-6),
            if inst.gated_off { "yes" } else { "-" }.to_string(),
        ]);
    }
    t
}

/// `capstore fleet --rank`: sweep the scenario's (network, tech) pair,
/// take the Pareto front, and pick the design mix + dispatch policy
/// minimizing SLO-feasible energy per served inference.
fn run_rank(
    ev: &Evaluator,
    sc: &Scenario,
    profile: &crate::traffic::TrafficProfile,
    policy: &crate::coordinator::BatchPolicy,
    fleet: &FleetSpec,
) -> Result<Output> {
    let mut ex = Explorer::new(sc.network.clone());
    ex.model.tech = sc.tech.technology();
    let points = ex.sweep()?;
    let front = Explorer::pareto(&points);
    let winner = rank_fleet(ev, sc, &front, profile, policy, fleet)?;

    let mut t = Table::new(
        "fleet DSE — winning design mix",
        &["inst", "org", "banks", "sectors", "dma"],
    );
    for (i, p) in winner.mix.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            p.organization.label().into(),
            p.banks.to_string(),
            p.sectors.to_string(),
            p.dma.model.label().into(),
        ]);
    }

    let rep = &winner.report;
    let mut out = Output::new();
    out.json = Json::obj(vec![
        ("network", Json::Str(sc.network.name.to_string())),
        ("tech", Json::Str(sc.tech.label().to_string())),
        ("front_points", Json::Num(front.len() as f64)),
        ("policy", Json::Str(winner.policy.label().into())),
        ("feasible", Json::Bool(winner.feasible)),
        ("mix", t.to_json()),
        ("report", rep.to_json()),
    ]);

    out.text(format!(
        "scenario: {} x {} instances | pattern {} seed {} duration \
         {}s slo {}ms",
        sc.label(),
        fleet.instances,
        profile.pattern.label(),
        profile.seed,
        profile.duration_secs,
        profile.slo_ms,
    ));
    out.text(format!(
        "front: {} Pareto points of a {}-point sweep\n",
        front.len(),
        points.len()
    ));
    out.table(t);
    out.text(format!(
        "\npolicy {}: {:.3} µJ/inference at {:.1} inf/s, {:.2}% SLO \
         misses ({}), {} of {} instances gated off whole",
        winner.policy.label(),
        rep.energy_uj_per_inference(),
        rep.throughput_per_sec(),
        100.0 * rep.slo_violation_fraction(),
        if winner.feasible { "ok" } else { "MISS" },
        rep.gated_off_instances,
        fleet.instances,
    ));
    let heterogeneous = winner
        .mix
        .windows(2)
        .any(|w| !w[0].bit_eq(&w[1]));
    if heterogeneous {
        out.text(
            "the winning fleet is heterogeneous — the low-index \
             prefix absorbs traffic while low-leakage designs sleep \
             in the tail",
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::Flags;
    use super::*;

    fn run_fleet(
        positionals: Vec<String>,
        flags: Flags,
    ) -> Result<Output> {
        let ctx = CommandContext::new("fleet", positionals, flags)?;
        FleetCmd.run(&ctx)
    }

    #[test]
    fn unknown_policy_is_a_typed_error_naming_the_choices() {
        let mut flags = Flags::new();
        flags.insert("policy".into(), "freshest-first".into());
        let err = run_fleet(Vec::new(), flags).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("round-robin"), "{msg}");
        assert!(msg.contains("jsq"), "{msg}");
        assert!(msg.contains("packing"), "{msg}");
    }

    #[test]
    fn degenerate_fleet_shapes_are_rejected() {
        for (key, value) in [
            ("instances", "0"),
            ("min-active", "0"),
            ("scale-up-depth", "0"),
        ] {
            let mut flags = Flags::new();
            flags.insert(key.into(), value.into());
            assert!(
                run_fleet(Vec::new(), flags).is_err(),
                "accepted --{key} {value}"
            );
        }
        // a floor above the fleet size is rejected too
        let mut flags = Flags::new();
        flags.insert("instances".into(), "2".into());
        flags.insert("min-active".into(), "3".into());
        assert!(run_fleet(Vec::new(), flags).is_err());
    }

    #[test]
    fn rank_rejects_pinned_design_axes() {
        for (key, value) in [
            ("org", "SMP"),
            ("banks", "4"),
            ("sectors", "8"),
            ("dma", "serial"),
            ("dma-bw", "32"),
        ] {
            let mut flags = Flags::new();
            flags.insert("rank".into(), String::new());
            flags.insert(key.into(), value.into());
            assert!(
                run_fleet(Vec::new(), flags).is_err(),
                "--rank accepted pinned --{key}"
            );
        }
        let mut flags = Flags::new();
        flags.insert("rank".into(), String::new());
        assert!(run_fleet(
            vec!["mnist".into(), "PG-SEP".into()],
            flags
        )
        .is_err());
        // --rank and --profile conflict
        let mut flags = Flags::new();
        flags.insert("rank".into(), String::new());
        flags.insert("profile".into(), String::new());
        assert!(run_fleet(Vec::new(), flags).is_err());
    }

    #[test]
    fn fleet_run_is_deterministic_and_conserves() {
        let run = || {
            let mut flags = Flags::new();
            flags.insert("rate".into(), "2000".into());
            flags.insert("duration".into(), "0.02".into());
            flags.insert("instances".into(), "3".into());
            flags.insert("policy".into(), "packing".into());
            flags.insert("format".into(), "json".into());
            run_fleet(Vec::new(), flags).unwrap().json.render()
        };
        let first = run();
        assert_eq!(first, run(), "same seed must be byte-identical");
        assert!(first.contains("\"instances\""));
        assert!(first.contains("\"policy\":\"packing\""));
    }
}
