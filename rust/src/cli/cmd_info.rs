//! `capstore info` — artifact manifest + environment summary;
//! extracted from the old monolith with bit-identical output.

use std::path::PathBuf;

use crate::capsnet::CapsNetConfig;
use crate::runtime::manifest::ArtifactManifest;
use crate::scenario::TechNode;
use crate::util::json::Json;
use crate::Result;

use super::context::CommandContext;
use super::output::Output;
use super::spec::{self, FlagSpec};
use super::Command;

pub struct Info;

impl Command for Info {
    fn name(&self) -> &'static str {
        "info"
    }

    fn about(&self) -> &'static str {
        "artifact manifest + environment summary"
    }

    fn groups(&self) -> &'static [&'static [FlagSpec]] {
        &[spec::INFO]
    }

    fn run(&self, ctx: &CommandContext) -> Result<Output> {
        let rc = ctx.run_config();
        let dir = PathBuf::from(&rc.artifact_dir);
        let m = ArtifactManifest::load(&dir)?;

        let mut out = Output::new();
        out.text(format!("artifact dir: {}", dir.display()));
        out.text(format!(
            "networks:     {}",
            CapsNetConfig::names().join(", ")
        ));
        out.text(format!("tech nodes:   {}", TechNode::names().join(", ")));
        out.text(format!("param order:  {:?}", m.param_order));

        let mut networks: Vec<Json> = Vec::new();
        for (name, entry) in &m.configs {
            let validated = if let Some(cfg) = CapsNetConfig::by_name(name) {
                m.validate_against(name, &cfg)?;
                true
            } else {
                false
            };
            out.text(format!(
                "config {name}: batches {:?}, {} ops, weights {} ({} params)",
                entry.model.keys().collect::<Vec<_>>(),
                entry.ops.len(),
                entry.weights,
                entry.num_params
            ));
            if validated {
                out.text("  geometry cross-check vs rust model: OK");
            }
            networks.push(Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("ops", Json::Num(entry.ops.len() as f64)),
                ("num_params", Json::Num(entry.num_params as f64)),
                ("validated", Json::Bool(validated)),
            ]));
        }
        out.json = Json::obj(vec![
            ("artifact_dir", Json::Str(dir.display().to_string())),
            ("networks", Json::str_arr(CapsNetConfig::names())),
            ("configs", Json::Arr(networks)),
        ]);
        Ok(out)
    }
}
