//! `capstore check` — the static diagnostics engine, CLI edition.
//!
//! Runs every rule in [`crate::analysis::check`] against one resolved
//! scenario (flags, `--scenario <file>`, or a bare positional path) or
//! against every file under `examples/scenarios/` with
//! `--all-examples`.  No `Timeline` is built and no event loop runs:
//! the command's whole job is to reject infeasible work before the
//! expensive commands start.  Error-severity findings set
//! [`Output::failed`], so the process exits nonzero while still
//! printing the full report in either format.

use crate::analysis::check::{check_scenario, CheckReport};
use crate::config::toml::TomlDoc;
use crate::scenario::Scenario;
use crate::util::json::Json;
use crate::{Error, Result};

use super::context::CommandContext;
use super::output::Output;
use super::spec::{self, FlagSpec};
use super::Command;

/// Where `--all-examples` looks for scenario files, relative to the
/// working directory; the crate is nested one level below the repo
/// root (which owns `examples/`), so both vantage points are tried.
const EXAMPLE_DIRS: &[&str] = &["examples/scenarios", "../examples/scenarios"];

pub struct Check;

impl Command for Check {
    fn name(&self) -> &'static str {
        "check"
    }

    fn about(&self) -> &'static str {
        "static diagnostics: lint a scenario without simulating it"
    }

    fn groups(&self) -> &'static [&'static [FlagSpec]] {
        &[spec::SCENARIO, spec::MEMORY, spec::TIME, spec::CHECK]
    }

    fn max_positionals(&self) -> usize {
        1
    }

    fn positional_usage(&self) -> &'static str {
        "[<scenario.toml>]"
    }

    fn long_help(&self) -> &'static str {
        "Checks the resolved scenario against the static rule catalogue \
         (stable CAPnnn codes; see docs/USER_GUIDE.md) without building \
         a timeline or running the event loop: geometry quantization \
         waste, ignored keys, SLOs below the static service floor, \
         overload, gating break-even violations, and degenerate \
         [traffic]/[faults] sections.  Errors exit nonzero; warnings \
         do not.  A bare path positional is shorthand for --scenario; \
         --all-examples checks every file under examples/scenarios/."
    }

    fn run(&self, ctx: &CommandContext) -> Result<Output> {
        let targets = resolve_targets(ctx)?;

        let mut out = Output::new();
        let mut scenarios = Vec::new();
        let mut total_errors = 0;
        let mut total_warnings = 0;
        for (file, sc, doc) in &targets {
            let report = check_scenario(sc, doc.as_ref())?;
            total_errors += report.errors();
            total_warnings += report.warnings();
            render_report(&mut out, file.as_deref(), &report);
            scenarios.push(report_json(file.as_deref(), &report));
        }

        out.text(format!(
            "\nchecked {} scenario(s): {} error(s), {} warning(s)",
            targets.len(),
            total_errors,
            total_warnings,
        ));
        out.json = Json::obj(vec![
            ("checked", Json::Num(targets.len() as f64)),
            ("errors", Json::Num(total_errors as f64)),
            ("warnings", Json::Num(total_warnings as f64)),
            ("scenarios", Json::Arr(scenarios)),
        ]);
        out.failed = total_errors > 0;
        Ok(out)
    }
}

/// The static pre-flight `evaluate`/`dse`/`traffic` run before any
/// simulation: error-severity diagnostics abort with each finding
/// listed; warnings stay silent here (run `capstore check` for the
/// full report) so the simulating commands' output is byte-identical
/// to the pre-check CLI.  `--no-check` skips the whole thing.
pub(super) fn preflight(
    ctx: &CommandContext,
    sc: &Scenario,
    doc: Option<&TomlDoc>,
) -> Result<()> {
    if ctx.flag("no-check").is_some() {
        return Ok(());
    }
    let report = check_scenario(sc, doc)?;
    if report.passed() {
        return Ok(());
    }
    let errors: Vec<String> = report
        .diagnostics
        .iter()
        .filter(|d| d.severity.is_error())
        .map(|d| d.render())
        .collect();
    Err(Error::Config(format!(
        "static check failed for {} (`capstore check` shows the full \
         report; --no-check overrides):\n  {}",
        report.label,
        errors.join("\n  "),
    )))
}

/// What to check: `(source file, scenario, parsed doc)` triples.  The
/// doc rides along because the ignored-key rule (CAP002) only fires on
/// keys the user actually wrote.
type Target = (Option<String>, Scenario, Option<TomlDoc>);

fn resolve_targets(ctx: &CommandContext) -> Result<Vec<Target>> {
    let all_examples = ctx.flag("all-examples").is_some();
    let positional = ctx.positionals.first();

    if all_examples && (positional.is_some() || ctx.flag("scenario").is_some())
    {
        return Err(Error::Config(
            "--all-examples conflicts with naming a single scenario \
             (positional path or --scenario)"
                .into(),
        ));
    }
    if let (Some(p), Some(_)) = (positional, ctx.flag("scenario")) {
        return Err(Error::Config(format!(
            "`check {p}` and `--scenario` both name the file — give \
             one or the other"
        )));
    }

    if all_examples {
        let dir = EXAMPLE_DIRS
            .iter()
            .find(|d| std::path::Path::new(d).is_dir())
            .ok_or_else(|| {
                Error::Config(format!(
                    "--all-examples: none of {} exists here",
                    EXAMPLE_DIRS.join(", ")
                ))
            })?;
        let mut paths: Vec<String> = std::fs::read_dir(dir)
            .map_err(|e| Error::Config(format!("--all-examples: {dir}: {e}")))?
            .filter_map(|entry| {
                let p = entry.ok()?.path();
                let name = p.to_str()?;
                name.ends_with(".toml").then(|| name.to_string())
            })
            .collect();
        paths.sort();
        if paths.is_empty() {
            return Err(Error::Config(format!(
                "--all-examples: no .toml files under {dir}"
            )));
        }
        return paths.into_iter().map(|p| load_target(&p)).collect();
    }

    if let Some(path) = positional {
        return Ok(vec![load_target(path)?]);
    }

    // the shared flag stack: defaults -> --config -> --scenario -> flags
    Ok(vec![(
        ctx.flag("scenario").map(str::to_string),
        ctx.scenario()?,
        ctx.scenario_doc().cloned(),
    )])
}

/// Load one scenario file the way `--scenario <path>` would (doc-only,
/// no flag overlay — a batch check has no meaningful flag layer).
fn load_target(path: &str) -> Result<Target> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("{path}: {e}")))?;
    let doc = TomlDoc::parse(&text)?;
    let sc = Scenario::builder().overlay_toml(&doc)?.build()?;
    Ok((Some(path.to_string()), sc, Some(doc)))
}

fn render_report(out: &mut Output, file: Option<&str>, report: &CheckReport) {
    match file {
        Some(f) => out.text(format!("== check {} ({f}) ==", report.label)),
        None => out.text(format!("== check {} ==", report.label)),
    };
    for d in &report.diagnostics {
        out.text(format!("  {}", d.render()));
    }
    if report.diagnostics.is_empty() {
        out.text("  ok — no findings");
    }
    let be = match report.bounds.break_even_cycles {
        Some(be) => format!("{be} cycles"),
        None => "- (ungated)".into(),
    };
    out.text(format!(
        "  bounds: service floor {:.3} ms ({} cycles), capacity \
         {:.0}/s, gating break-even {}",
        report.bounds.service_ms,
        report.bounds.service_cycles,
        report.bounds.capacity_per_sec,
        be,
    ));
}

fn report_json(file: Option<&str>, report: &CheckReport) -> Json {
    Json::obj(vec![
        ("scenario", Json::Str(report.label.clone())),
        (
            "file",
            match file {
                Some(f) => Json::Str(f.to_string()),
                None => Json::Null,
            },
        ),
        ("passed", Json::Bool(report.passed())),
        ("errors", Json::Num(report.errors() as f64)),
        ("warnings", Json::Num(report.warnings() as f64)),
        (
            "diagnostics",
            Json::Arr(report.diagnostics.iter().map(|d| d.to_json()).collect()),
        ),
        ("bounds", report.bounds.to_json()),
    ])
}
