//! `capstore analyze` — the paper's §3 analysis (Fig 4a-e + Eq 1/2),
//! extracted verbatim from the old monolith; output is bit-identical.

use crate::accel::systolic::SystolicSim;
use crate::analysis::offchip::OffChipTraffic;
use crate::analysis::requirements::RequirementsAnalysis;
use crate::capsnet::Operation;
use crate::report::Table;
use crate::util::json::Json;
use crate::util::units::{fmt_bytes, fmt_si};
use crate::Result;

use super::context::CommandContext;
use super::output::Output;
use super::spec::{self, FlagSpec};
use super::Command;

pub struct Analyze;

impl Command for Analyze {
    fn name(&self) -> &'static str {
        "analyze"
    }

    fn about(&self) -> &'static str {
        "the paper's §3 analysis (Fig 4a-e + Eq 1/2 tables)"
    }

    fn groups(&self) -> &'static [&'static [FlagSpec]] {
        &[spec::SCENARIO]
    }

    fn run(&self, ctx: &CommandContext) -> Result<Output> {
        let sc = ctx.scenario()?;
        let cfg = sc.network.clone();
        let sim = SystolicSim::default();
        let req = RequirementsAnalysis::analyze(&cfg, &sim.array);
        let cap = req.max_total();

        let mut t_req = Table::new(
            "Fig 4a/4c — on-chip memory requirements per operation (bytes)",
            &["op", "data", "weight", "accum", "total", "util%"],
        );
        for o in &req.per_op {
            t_req.row(vec![
                o.kind.label().to_string(),
                o.req.data.to_string(),
                o.req.weight.to_string(),
                o.req.accum.to_string(),
                o.req.total().to_string(),
                format!("{:.1}", 100.0 * o.req.total() as f64 / cap as f64),
            ]);
        }

        let mut t_cycles = Table::new(
            "Fig 4b — clock cycles per operation",
            &["op", "execs", "cycles", "total"],
        );
        for op in Operation::all_kinds(&cfg) {
            let p = sim.profile(&op);
            let execs = op.kind.executions(&cfg);
            t_cycles.row(vec![
                op.kind.label().into(),
                execs.to_string(),
                fmt_si(p.cycles),
                fmt_si(p.cycles * execs),
            ]);
        }
        let (_, total) = sim.profile_schedule(&cfg);
        let inference_ms = total as f64 / sim.array.clock_hz * 1e3;

        let mut t_acc = Table::new(
            "Fig 4d/4e — on-chip accesses per operation (per execution)",
            &["op", "data R", "data W", "wt R", "wt W", "acc R", "acc W"],
        );
        for op in Operation::all_kinds(&cfg) {
            let p = sim.profile(&op);
            t_acc.row(vec![
                op.kind.label().into(),
                fmt_si(p.data_reads),
                fmt_si(p.data_writes),
                fmt_si(p.weight_reads),
                fmt_si(p.weight_writes),
                fmt_si(p.accum_reads),
                fmt_si(p.accum_writes),
            ]);
        }

        let mut t_off = Table::new(
            "Eq (1)/(2) — off-chip accesses per operation",
            &["op", "reads", "writes"],
        );
        for tr in OffChipTraffic::analyze(&cfg, &sim) {
            t_off.row(vec![
                tr.kind.label().into(),
                fmt_si(tr.reads),
                fmt_si(tr.writes),
            ]);
        }
        let dram_bytes = OffChipTraffic::total_bytes(&cfg, &sim);

        let mut out = Output::new();
        out.json = Json::obj(vec![
            ("network", Json::Str(cfg.name.to_string())),
            (
                "tables",
                Json::Arr(vec![
                    t_req.to_json(),
                    t_cycles.to_json(),
                    t_acc.to_json(),
                    t_off.to_json(),
                ]),
            ),
            ("worst_case_bytes", Json::Num(cap as f64)),
            ("total_cycles", Json::Num(total as f64)),
            ("inference_ms", Json::Num(inference_ms)),
            ("dram_bytes_per_inference", Json::Num(dram_bytes as f64)),
        ]);

        out.table(t_req);
        out.text(format!(
            "overall worst case (dashed line): {}\n",
            fmt_bytes(cap)
        ));
        out.table(t_cycles);
        out.text(format!(
            "inference total: {} cycles = {:.3} ms @ {:.1} GHz\n",
            fmt_si(total),
            inference_ms,
            sim.array.clock_hz / 1e9
        ));
        out.table(t_acc);
        out.blank();
        out.table(t_off);
        out.text(format!(
            "total DRAM bytes per inference: {}",
            fmt_bytes(dram_bytes)
        ));
        Ok(out)
    }
}
