//! The declarative flag registry.
//!
//! Every CLI flag is one [`FlagSpec`] value and flags are composed into
//! reusable groups ([`SCENARIO`], [`MEMORY`], [`TIME`], [`TRAFFIC`],
//! [`DSE`], ...).  Everything user-facing — known-flag rejection in the
//! parser, `usage()`, `capstore help <cmd>`, shell completions, the
//! USER_GUIDE reference — *derives* from these specs, so adding a flag
//! is a one-line change that can never drift out of sync with the help
//! text (the old monolith kept five hand-synced `match cmd` sites).

use crate::capsnet::CapsNetConfig;
use crate::capstore::arch::Organization;
use crate::scenario::TechNode;
use crate::traffic::ArrivalPattern;

/// How a flag's value is interpreted — drives help hints and shell
/// completions.  Value *parsing* stays in the command context so error
/// messages are unchanged from the pre-registry CLI; the kind is
/// metadata, not a validator.
#[derive(Debug, Clone, Copy)]
pub enum ValueKind {
    /// Filesystem path.
    Path,
    /// Unsigned integer.
    UInt,
    /// Floating-point number.
    Float,
    /// Comma-separated list of numbers.
    List,
    /// One of a fixed set of words.
    Choice(&'static [&'static str]),
    /// One of a runtime registry's names (networks, nodes, patterns).
    DynChoice(fn() -> Vec<&'static str>),
    /// Boolean switch: the flag takes no value token.
    Switch,
}

impl ValueKind {
    /// The candidate values for this flag, if enumerable (used by the
    /// completion scripts).
    pub fn choices(&self) -> Vec<&'static str> {
        match self {
            ValueKind::Choice(c) => c.to_vec(),
            ValueKind::DynChoice(f) => f(),
            _ => Vec::new(),
        }
    }

    /// Whether the flag consumes a value token.
    pub fn takes_value(&self) -> bool {
        !matches!(self, ValueKind::Switch)
    }
}

/// The group a flag belongs to; `capstore help <cmd>` renders a
/// section label when consecutive flags change group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlagGroup {
    /// Scenario selection + output, shared by the evaluation commands.
    Scenario,
    /// The memory-system axes of a scenario.
    Memory,
    /// The time-policy axes of a scenario (timeline IR knobs).
    Time,
    /// The serving-simulation workload knobs.
    Traffic,
    /// Fleet sharding knobs (instances, dispatch policy, elasticity).
    Fleet,
    /// Fault injection and resilience policy knobs.
    Faults,
    /// Design-space exploration controls.
    Dse,
    /// PJRT serving / artifact knobs.
    Serve,
    /// Help-only switches.
    Help,
}

impl FlagGroup {
    /// The section label shown in `capstore help <cmd>`.
    pub fn label(&self) -> &'static str {
        match self {
            FlagGroup::Scenario => "scenario selection & output",
            FlagGroup::Memory => "memory axes",
            FlagGroup::Time => "time-policy axes",
            FlagGroup::Traffic => "serving workload",
            FlagGroup::Fleet => "fleet sharding",
            FlagGroup::Faults => "faults & resilience",
            FlagGroup::Dse => "exploration",
            FlagGroup::Serve => "serving / artifacts",
            FlagGroup::Help => "help",
        }
    }
}

/// One declared flag: the single source of truth its command's parser,
/// help text, and completions all derive from.
#[derive(Debug, Clone, Copy)]
pub struct FlagSpec {
    /// Flag name without the `--` prefix.
    pub name: &'static str,
    /// Value kind (metadata for hints/completions, not a validator).
    pub kind: ValueKind,
    /// Value placeholder in help text, e.g. `<path.toml>` or `N`.
    pub hint: &'static str,
    /// One-line description shown in `usage()` and `help <cmd>`.
    pub doc: &'static str,
    /// Rendered as `[default]` in help; empty = no default shown.
    pub default: &'static str,
    pub group: FlagGroup,
}

// --- dynamic choice sources (the existing registries) ----------------

fn model_names() -> Vec<&'static str> {
    CapsNetConfig::names()
}

fn tech_names() -> Vec<&'static str> {
    TechNode::names()
}

fn pattern_names() -> Vec<&'static str> {
    ArrivalPattern::names()
}

fn org_names() -> Vec<&'static str> {
    Organization::all().iter().map(|o| o.label()).collect()
}

fn dma_names() -> Vec<&'static str> {
    crate::timeline::DmaModel::names()
}

fn policy_names() -> Vec<&'static str> {
    crate::fleet::DispatchPolicy::names()
}

// --- the flags -------------------------------------------------------

pub const SCENARIO_FILE: FlagSpec = FlagSpec {
    name: "scenario",
    kind: ValueKind::Path,
    hint: "<path.toml>",
    doc: "typed scenario file (network/tech/org/geometry/batch/gating/\
          dma/traffic/faults); individual flags override its fields",
    default: "",
    group: FlagGroup::Scenario,
};

pub const FORMAT: FlagSpec = FlagSpec {
    name: "format",
    kind: ValueKind::Choice(&["table", "json"]),
    hint: "<table|json>",
    doc: "output format",
    default: "table",
    group: FlagGroup::Scenario,
};

pub const MODEL: FlagSpec = FlagSpec {
    name: "model",
    kind: ValueKind::DynChoice(model_names),
    hint: "<name>",
    doc: "network config (`capstore info` lists the registry)",
    default: "mnist",
    group: FlagGroup::Scenario,
};

pub const CONFIG: FlagSpec = FlagSpec {
    name: "config",
    kind: ValueKind::Path,
    hint: "<path.toml>",
    doc: "legacy run config file (server knobs + memory fields)",
    default: "",
    group: FlagGroup::Scenario,
};

pub const TECH: FlagSpec = FlagSpec {
    name: "tech",
    kind: ValueKind::DynChoice(tech_names),
    hint: "<node>",
    doc: "technology node",
    default: "32nm",
    group: FlagGroup::Memory,
};

pub const ORG: FlagSpec = FlagSpec {
    name: "org",
    kind: ValueKind::DynChoice(org_names),
    hint: "<org>",
    doc: "memory organization (Table 1)",
    default: "PG-SEP",
    group: FlagGroup::Memory,
};

pub const BANKS: FlagSpec = FlagSpec {
    name: "banks",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "SRAM banks per macro",
    default: "16",
    group: FlagGroup::Memory,
};

pub const SECTORS: FlagSpec = FlagSpec {
    name: "sectors",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "power-gating sectors per bank",
    default: "64",
    group: FlagGroup::Memory,
};

pub const LOOKAHEAD: FlagSpec = FlagSpec {
    name: "lookahead",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "PMU pre-wake cycles before an op boundary (0 = lazy)",
    default: "256",
    group: FlagGroup::Time,
};

pub const DMA: FlagSpec = FlagSpec {
    name: "dma",
    kind: ValueKind::DynChoice(dma_names),
    hint: "<instant|serial|double-buffered>",
    doc: "DMA/compute overlap model",
    default: "instant",
    group: FlagGroup::Time,
};

pub const DMA_BW: FlagSpec = FlagSpec {
    name: "dma-bw",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "DMA bytes per array cycle",
    default: "16",
    group: FlagGroup::Time,
};

pub const BATCH: FlagSpec = FlagSpec {
    name: "batch",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "pipelined back-to-back inferences per batch",
    default: "1",
    group: FlagGroup::Time,
};

pub const ARTIFACTS: FlagSpec = FlagSpec {
    name: "artifacts",
    kind: ValueKind::Path,
    hint: "<dir>",
    doc: "AOT artifact directory",
    default: "artifacts",
    group: FlagGroup::Serve,
};

pub const THREADS: FlagSpec = FlagSpec {
    name: "threads",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "worker threads (0 = all cores)",
    default: "0",
    group: FlagGroup::Dse,
};

pub const SPACE: FlagSpec = FlagSpec {
    name: "space",
    kind: ValueKind::Choice(&["default", "large", "huge", "full"]),
    hint: "<default|large|huge|full>",
    doc: "sweep extent (full = all tech nodes x all models, narrowed \
          by --model/--tech; large/huge/full cross the dma axis too; \
          huge is the >=100k-point scale space)",
    default: "default",
    group: FlagGroup::Dse,
};

pub const PRUNE: FlagSpec = FlagSpec {
    name: "prune",
    kind: ValueKind::Choice(&["on", "off"]),
    hint: "<on|off>",
    doc: "dominance-aware branch-and-bound: skip geometry subtrees the \
          incumbent Pareto front already strictly dominates (the front \
          is bit-identical either way)",
    default: "off",
    group: FlagGroup::Dse,
};

pub const RATE: FlagSpec = FlagSpec {
    name: "rate",
    kind: ValueKind::Float,
    hint: "R",
    doc: "mean arrivals per second",
    default: "1000",
    group: FlagGroup::Traffic,
};

pub const RATES: FlagSpec = FlagSpec {
    name: "rates",
    kind: ValueKind::List,
    hint: "R1,R2,...",
    doc: "serving-aware DSE: re-rank the Pareto front per rate and \
          report each winner (conflicts with --rate and any pinned \
          design-point axis)",
    default: "",
    group: FlagGroup::Traffic,
};

pub const PATTERN: FlagSpec = FlagSpec {
    name: "pattern",
    kind: ValueKind::DynChoice(pattern_names),
    hint: "<poisson|bursty|diurnal>",
    doc: "arrival process",
    default: "poisson",
    group: FlagGroup::Traffic,
};

pub const SEED: FlagSpec = FlagSpec {
    name: "seed",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "arrival RNG seed",
    default: "1",
    group: FlagGroup::Traffic,
};

pub const DURATION: FlagSpec = FlagSpec {
    name: "duration",
    kind: ValueKind::Float,
    hint: "S",
    doc: "simulated window, seconds of virtual time",
    default: "1",
    group: FlagGroup::Traffic,
};

pub const SLO_MS: FlagSpec = FlagSpec {
    name: "slo-ms",
    kind: ValueKind::Float,
    hint: "MS",
    doc: "per-request latency objective, milliseconds",
    default: "10",
    group: FlagGroup::Traffic,
};

pub const MAX_BATCH: FlagSpec = FlagSpec {
    name: "max-batch",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "batcher size trigger",
    default: "8",
    group: FlagGroup::Traffic,
};

pub const MAX_WAIT_MS: FlagSpec = FlagSpec {
    name: "max-wait-ms",
    kind: ValueKind::Float,
    hint: "MS",
    doc: "batcher wait trigger, milliseconds",
    default: "2",
    group: FlagGroup::Traffic,
};

pub const INSTANCES: FlagSpec = FlagSpec {
    name: "instances",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "fleet size (accelerator instances sharing the request stream)",
    default: "2",
    group: FlagGroup::Fleet,
};

pub const POLICY: FlagSpec = FlagSpec {
    name: "policy",
    kind: ValueKind::DynChoice(policy_names),
    hint: "<round-robin|jsq|packing>",
    doc: "dispatch policy (packing bin-packs load so idle instances \
          gate off whole)",
    default: "round-robin",
    group: FlagGroup::Fleet,
};

pub const ELASTIC: FlagSpec = FlagSpec {
    name: "elastic",
    kind: ValueKind::Switch,
    hint: "",
    doc: "elastic scaling: start at --min-active instances and grow/\
          shrink the active set on queue depth (wakes pay the cold \
          premium)",
    default: "",
    group: FlagGroup::Fleet,
};

pub const SCALE_UP_DEPTH: FlagSpec = FlagSpec {
    name: "scale-up-depth",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "queued requests per active instance that trigger a scale-up",
    default: "8",
    group: FlagGroup::Fleet,
};

pub const MIN_ACTIVE: FlagSpec = FlagSpec {
    name: "min-active",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "elastic floor: never park below this many active instances",
    default: "1",
    group: FlagGroup::Fleet,
};

pub const RANK_FLEET: FlagSpec = FlagSpec {
    name: "rank",
    kind: ValueKind::Switch,
    hint: "",
    doc: "fleet-level DSE: sweep the (network, tech) Pareto front and \
          pick the design mix + dispatch policy minimizing SLO-feasible \
          energy per served inference (conflicts with any pinned \
          design-point axis)",
    default: "",
    group: FlagGroup::Fleet,
};

pub const FAULTS: FlagSpec = FlagSpec {
    name: "faults",
    kind: ValueKind::Path,
    hint: "<path.toml>",
    doc: "fault plan file (a bare `[faults]` TOML section); overrides \
          the scenario's section, and the flags below override its \
          fields",
    default: "",
    group: FlagGroup::Faults,
};

pub const WAKE_FAIL_RATE: FlagSpec = FlagSpec {
    name: "wake-fail-rate",
    kind: ValueKind::Float,
    hint: "P",
    doc: "probability each sector wake attempt fails (retried with \
          exponential backoff up to the plan's retry cap)",
    default: "0",
    group: FlagGroup::Faults,
};

pub const QUEUE_CAP: FlagSpec = FlagSpec {
    name: "queue-cap",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "admission control: shed arrivals beyond this backlog",
    default: "",
    group: FlagGroup::Faults,
};

pub const RETRY_BUDGET: FlagSpec = FlagSpec {
    name: "retry-budget",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "re-queues granted to a timed-out request (needs a timeout; \
          defaults the timeout to the SLO when --timeout-ms is absent)",
    default: "0",
    group: FlagGroup::Faults,
};

pub const TIMEOUT_MS: FlagSpec = FlagSpec {
    name: "timeout-ms",
    kind: ValueKind::Float,
    hint: "MS",
    doc: "expire requests older than this at dispatch assembly",
    default: "",
    group: FlagGroup::Faults,
};

pub const WAKE_FALLBACK: FlagSpec = FlagSpec {
    name: "wake-fallback",
    kind: ValueKind::Float,
    hint: "P",
    doc: "stop gating for the rest of the run once the observed \
          wake-failure rate reaches P (all-on fallback)",
    default: "",
    group: FlagGroup::Faults,
};

pub const REQUESTS: FlagSpec = FlagSpec {
    name: "requests",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "request count",
    default: "64",
    group: FlagGroup::Serve,
};

pub const CLIENTS: FlagSpec = FlagSpec {
    name: "clients",
    kind: ValueKind::UInt,
    hint: "N",
    doc: "client threads",
    default: "4",
    group: FlagGroup::Serve,
};

pub const ALL: FlagSpec = FlagSpec {
    name: "all",
    kind: ValueKind::Switch,
    hint: "",
    doc: "dump the full command/flag reference for every command",
    default: "",
    group: FlagGroup::Help,
};

pub const NO_CHECK: FlagSpec = FlagSpec {
    name: "no-check",
    kind: ValueKind::Switch,
    hint: "",
    doc: "skip the static pre-flight (`capstore check`) that otherwise \
          aborts on error-severity diagnostics before simulating",
    default: "",
    group: FlagGroup::Scenario,
};

pub const OUT: FlagSpec = FlagSpec {
    name: "out",
    kind: ValueKind::Path,
    hint: "<path.json>",
    doc: "trace output path (Chrome trace-event JSON; open it at \
          ui.perfetto.dev)",
    default: "trace.json",
    group: FlagGroup::Scenario,
};

pub const TRACE_TRAFFIC: FlagSpec = FlagSpec {
    name: "traffic",
    kind: ValueKind::Switch,
    hint: "",
    doc: "trace a seeded serving run (request arcs, batches, queue \
          depth, fault windows) instead of one batch timeline",
    default: "",
    group: FlagGroup::Traffic,
};

pub const PROFILE: FlagSpec = FlagSpec {
    name: "profile",
    kind: ValueKind::Switch,
    hint: "",
    doc: "append the deterministic counters section (stable dotted \
          names; see docs/USER_GUIDE.md for the reference table)",
    default: "",
    group: FlagGroup::Scenario,
};

pub const ALL_EXAMPLES: FlagSpec = FlagSpec {
    name: "all-examples",
    kind: ValueKind::Switch,
    hint: "",
    doc: "check every scenario file under examples/scenarios/ instead \
          of a single scenario",
    default: "",
    group: FlagGroup::Scenario,
};

// --- the composable groups -------------------------------------------
//
// A command's `groups()` concatenates these; the parser, help, and
// completions all see the concatenation, so a future flag is added in
// exactly one place.

/// Scenario selection + output, shared by the evaluation commands.
pub const SCENARIO: &[FlagSpec] = &[SCENARIO_FILE, FORMAT, MODEL, CONFIG];

/// The memory-system axes of a scenario.
pub const MEMORY: &[FlagSpec] = &[TECH, ORG, BANKS, SECTORS];

/// The time-policy axes of a scenario (timeline IR knobs).
pub const TIME: &[FlagSpec] = &[LOOKAHEAD, DMA, DMA_BW, BATCH];

/// [`TIME`] minus `--batch`: the traffic simulator's own batcher
/// decides actual batch sizes (use `--max-batch`), so a `--batch` pin
/// would be silently ignored — and this CLI rejects rather than
/// ignores.
pub const TIME_UNBATCHED: &[FlagSpec] = &[LOOKAHEAD, DMA, DMA_BW];

/// The serving-simulation workload knobs.
pub const TRAFFIC: &[FlagSpec] = &[
    RATE, RATES, PATTERN, SEED, DURATION, SLO_MS, MAX_BATCH, MAX_WAIT_MS,
];

/// [`TRAFFIC`] minus `--rates`: `capstore fleet` has its own DSE
/// switch (`--rank`), so a `--rates` list would be ambiguous there.
pub const TRAFFIC_ONE: &[FlagSpec] = &[
    RATE, PATTERN, SEED, DURATION, SLO_MS, MAX_BATCH, MAX_WAIT_MS,
];

/// Fleet sharding knobs (`capstore fleet`).
pub const FLEET: &[FlagSpec] = &[
    INSTANCES, POLICY, ELASTIC, SCALE_UP_DEPTH, MIN_ACTIVE, RANK_FLEET,
];

/// Fault injection + resilience policy knobs (`capstore traffic`).
pub const FAULT_KNOBS: &[FlagSpec] = &[
    FAULTS,
    WAKE_FAIL_RATE,
    QUEUE_CAP,
    RETRY_BUDGET,
    TIMEOUT_MS,
    WAKE_FALLBACK,
];

/// Design-space exploration controls.
pub const DSE: &[FlagSpec] = &[THREADS, SPACE, PRUNE];

/// `--tech` alone: `dse` pins the workload node but explores the
/// org/geometry/dma axes itself, so the rest of [`MEMORY`] is rejected
/// there.
pub const TECH_ONLY: &[FlagSpec] = &[TECH];

/// PJRT serving knobs.
pub const SERVE: &[FlagSpec] = &[ARTIFACTS, REQUESTS, CLIENTS];

/// `info`'s flags.
pub const INFO: &[FlagSpec] = &[CONFIG, FORMAT, ARTIFACTS];

/// The static pre-flight opt-out shared by `evaluate`/`dse`/`traffic`.
pub const PREFLIGHT: &[FlagSpec] = &[NO_CHECK];

/// `trace`'s own flags.
pub const TRACE: &[FlagSpec] = &[OUT, TRACE_TRAFFIC];

/// The `--profile` opt-in shared by `evaluate`/`dse`/`traffic`.
pub const PROFILE_ONLY: &[FlagSpec] = &[PROFILE];

/// `check`'s own switches.
pub const CHECK: &[FlagSpec] = &[ALL_EXAMPLES];

/// `help`'s flags.
pub const HELP: &[FlagSpec] = &[ALL];
