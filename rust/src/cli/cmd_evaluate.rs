//! `capstore evaluate` — Tables 1/2, Figs 5/10/11, plus the full
//! evaluation of the selected scenario; extracted from the old
//! monolith with bit-identical output.

use crate::capstore::arch::{Organization, DEFAULT_BANKS, DEFAULT_SECTORS};
use crate::report::paper::PaperReference;
use crate::report::Table;
use crate::scenario::{Evaluator, Geometry, Scenario};
use crate::telemetry::CounterRegistry;
use crate::timeline::Timeline;
use crate::util::json::Json;
use crate::util::units::{fmt_bytes, fmt_energy_uj, fmt_si};
use crate::Result;

use super::context::CommandContext;
use super::output::Output;
use super::spec::{self, FlagSpec};
use super::Command;

pub struct Evaluate;

impl Command for Evaluate {
    fn name(&self) -> &'static str {
        "evaluate"
    }

    fn about(&self) -> &'static str {
        "Table 1/2 + Fig 10 views + one Scenario evaluation"
    }

    fn groups(&self) -> &'static [&'static [FlagSpec]] {
        &[
            spec::SCENARIO,
            spec::MEMORY,
            spec::TIME,
            spec::PROFILE_ONLY,
            spec::PREFLIGHT,
        ]
    }

    fn run(&self, ctx: &CommandContext) -> Result<Output> {
        let sc = ctx.scenario()?;
        // static pre-flight: error-severity diagnostics abort before
        // any evaluation work (--no-check skips)
        super::cmd_check::preflight(ctx, &sc, ctx.scenario_doc())?;
        let profiling = ctx.flags.contains_key("profile");
        let builds_before = Timeline::build_count();
        let ev = Evaluator::new();
        let paper = PaperReference::new();

        // Tables 1/2: all six organizations at the paper's default
        // geometry for the scenario's network + node (one facade,
        // shared caches).
        let mut t1 = Table::new(
            "Table 1 — organizations (sizes in bytes)",
            &["org", "macro", "size", "banks", "sectors", "ports"],
        );
        let mut t2 = Table::new(
            "Table 2 — area and on-chip energy per organization",
            &["org", "area mm2", "energy/inf", "vs SMP", "paper vs SMP"],
        );
        let mut smp_energy = None;
        let mut org_evals = Vec::new();
        for org in Organization::all() {
            let org_sc = Scenario {
                organization: org,
                geometry: Geometry {
                    banks: DEFAULT_BANKS,
                    sectors: DEFAULT_SECTORS,
                },
                ..sc.clone()
            };
            let e = ev.evaluate_analytical(&org_sc)?;
            for m in &e.architecture.macros {
                t1.row(vec![
                    org.label().into(),
                    m.role.label().into(),
                    m.sram.size_bytes.to_string(),
                    m.sram.banks.to_string(),
                    m.sram.sectors.to_string(),
                    m.sram.ports.to_string(),
                ]);
            }
            if org.label() == "SMP" {
                smp_energy = Some(e.onchip_pj());
            }
            let vs_smp = smp_energy.map(|s| e.onchip_pj() / s).unwrap_or(1.0);
            let paper_ratio = paper
                .energy_vs_smp(org.label())
                .map(|r| format!("{r:.3}"))
                .unwrap_or_else(|| "-".into());
            t2.row(vec![
                org.label().into(),
                format!("{:.3}", e.area_mm2()),
                fmt_energy_uj(e.onchip_pj()),
                format!("{vs_smp:.3}"),
                paper_ratio,
            ]);
            org_evals.push(e);
        }

        // Fig 5 / Fig 11 headline systems (reusing the six evaluations)
        let a = ev.all_onchip_baseline(&sc)?;
        let by_label = |l: &str| {
            org_evals
                .iter()
                .find(|e| e.scenario.organization.label() == l)
                .expect("all six organizations evaluated")
        };
        let b = by_label("SMP").system.clone();
        let c = by_label("PG-SEP").system.clone();

        // the scenario actually selected: the only full evaluation
        // (with the event-level cross-check) — the table loop above is
        // analytical-only, so exactly one event sim runs per invocation
        let selected = ev.evaluate(&sc)?;

        let mut out = Output::new();
        let systems: Vec<Json> = [&a, &b, &c]
            .iter()
            .map(|sys| {
                Json::obj(vec![
                    ("label", Json::Str(sys.label.clone())),
                    ("accel_pj", Json::Num(sys.accel_pj)),
                    ("onchip_pj", Json::Num(sys.onchip_pj)),
                    ("offchip_pj", Json::Num(sys.offchip_pj)),
                    ("total_pj", Json::Num(sys.total_pj())),
                    ("memory_share", Json::Num(sys.memory_share())),
                ])
            })
            .collect();
        out.json = Json::obj(vec![
            ("table1", t1.to_json()),
            ("table2", t2.to_json()),
            ("systems", Json::Arr(systems)),
            // full Evaluation of the selected scenario (its own
            // "scenario" sub-object names the evaluated point)
            ("selected", selected.to_json()),
        ]);

        out.table(t1);
        out.blank();
        out.table(t2);
        out.text(
            "\n== Fig 5 / Fig 11 — whole-system energy per inference ==",
        );
        for sys in [&a, &b, &c] {
            out.text(format!(
                "{:18} accel {:>10}  onchip {:>10}  offchip {:>10}  total {:>10}  (memory {:.1}%)",
                sys.label,
                fmt_energy_uj(sys.accel_pj),
                fmt_energy_uj(sys.onchip_pj),
                fmt_energy_uj(sys.offchip_pj),
                fmt_energy_uj(sys.total_pj()),
                100.0 * sys.memory_share()
            ));
        }
        out.blank();
        out.text(PaperReference::delta_line(
            "hierarchy saving (b vs a)",
            1.0 - b.total_pj() / a.total_pj(),
            PaperReference::HIERARCHY_SAVING,
        ));
        out.text(PaperReference::delta_line(
            "PG-SEP on-chip saving vs (b)",
            1.0 - c.onchip_pj / b.onchip_pj,
            PaperReference::PG_SEP_ONCHIP_SAVING,
        ));
        out.text(PaperReference::delta_line(
            "PG-SEP total saving vs (a)",
            1.0 - c.total_pj() / a.total_pj(),
            PaperReference::PG_SEP_TOTAL_VS_A,
        ));
        out.text(PaperReference::delta_line(
            "PG-SEP total saving vs (b)",
            1.0 - c.total_pj() / b.total_pj(),
            PaperReference::PG_SEP_TOTAL_VS_B,
        ));

        out.text(format!("\n== scenario {} ==", selected.scenario.label()));
        out.text(format!(
            "onchip {}  offchip {}  accel {}  total {}",
            fmt_energy_uj(selected.onchip_pj()),
            fmt_energy_uj(selected.system.offchip_pj),
            fmt_energy_uj(selected.system.accel_pj),
            fmt_energy_uj(selected.total_pj()),
        ));
        out.text(format!(
            "area {:.3} mm2, capacity {}, batch {} -> {} per batch",
            selected.area_mm2(),
            fmt_bytes(selected.capacity_bytes()),
            selected.scenario.batch,
            fmt_energy_uj(selected.batch_pj()),
        ));
        if selected.timeline.stall_cycles() > 0 || selected.scenario.batch > 1
        {
            out.text(format!(
                "timeline: batch latency {} cycles ({} DMA stall), \
                 pipelining saves {}",
                fmt_si(selected.batch.latency_cycles),
                fmt_si(selected.timeline.stall_cycles()),
                fmt_energy_uj(selected.batch.pipeline_saving_pj),
            ));
        }
        if let Some(event) = &selected.event {
            out.text(format!(
                "event-sim: static {}  wakeup {}  transitions {}  stall cycles {}",
                fmt_energy_uj(event.static_pj),
                fmt_energy_uj(event.wakeup_pj),
                event.transitions,
                event.not_ready_cycles,
            ));
        }
        if profiling {
            // deterministic counters: the evaluation path is serial,
            // so the shared cost cache's hit/miss tallies are stable
            // here (unlike a threaded sweep, where they are excluded)
            let mut counters = CounterRegistry::new();
            counters.set(
                "timeline.builds",
                Timeline::build_count() - builds_before,
            );
            counters.set("cache.hits", ev.cost_cache().hits());
            counters.set("cache.misses", ev.cost_cache().misses());
            let snap = counters.snapshot();
            if let Json::Obj(m) = &mut out.json {
                m.insert(
                    "profile".into(),
                    Json::obj(vec![("counters", snap.to_json())]),
                );
            }
            out.blank();
            out.table(snap.table("profile — deterministic counters"));
        }
        Ok(out)
    }
}
