//! Registry-driven argument parsing.
//!
//! `<cmd> [positional]... [--flag value | --flag=value]...` — the known
//! flags, the positional budget, and the switch/value distinction all
//! come from the command's [`FlagSpec`](super::spec::FlagSpec) list, so
//! the parser can never drift from the help text.
//!
//! Unknown *commands* are rejected here, at parse time, with a
//! "did you mean" suggestion from the registry (they used to slip
//! through to the dispatcher with arbitrary flags attached and only
//! die later).  Flags a command does not consume and positionals
//! beyond what it accepts are errors, never silently ignored.

use crate::{Error, Result};

use super::registry;
use super::{Command, Flags};

/// One parsed invocation.
pub struct Invocation {
    /// `None` for a bare `capstore` (print usage, succeed).
    pub command: Option<&'static dyn Command>,
    pub positionals: Vec<String>,
    pub flags: Flags,
}

/// Parse an argument vector against the command registry.
pub fn parse(args: &[String]) -> Result<Invocation> {
    let name = args.first().map(String::as_str).unwrap_or("");
    if name.is_empty() {
        // bare `capstore` (or an empty argv token): print usage — but
        // trailing arguments have nothing to bind to, so reject them
        if args.len() > 1 {
            return Err(Error::Config(format!(
                "expected a subcommand before {:?}",
                args[1]
            )));
        }
        return Ok(Invocation {
            command: None,
            positionals: Vec::new(),
            flags: Flags::new(),
        });
    }
    let cmd = registry::find_or_suggest(name)?;
    let specs = cmd.flags();
    let max_pos = cmd.max_positionals();
    let mut positionals: Vec<String> = Vec::new();
    let mut flags = Flags::new();
    let mut i = 1;
    while i < args.len() {
        let Some(body) = args[i].strip_prefix("--") else {
            if positionals.len() < max_pos {
                positionals.push(args[i].clone());
                i += 1;
                continue;
            }
            return Err(Error::Config(format!(
                "expected --flag, got {:?}",
                args[i]
            )));
        };
        let (key, inline) = match body.split_once('=') {
            Some((k, v)) => (k, Some(v.to_string())),
            None => (body, None),
        };
        let spec = specs.iter().find(|s| s.name == key).ok_or_else(|| {
            Error::Config(format!(
                "unknown flag --{key} for `{}` (known: {})",
                cmd.name(),
                specs
                    .iter()
                    .map(|s| format!("--{}", s.name))
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })?;
        let value = if spec.kind.takes_value() {
            match inline {
                Some(v) => v,
                None => {
                    let v = args.get(i + 1).cloned().ok_or_else(|| {
                        Error::Config(format!("--{key} needs a value"))
                    })?;
                    i += 1;
                    v
                }
            }
        } else {
            if inline.is_some() {
                return Err(Error::Config(format!("--{key} takes no value")));
            }
            String::new()
        };
        flags.insert(key.to_string(), value);
        i += 1;
    }
    Ok(Invocation { command: Some(cmd), positionals, flags })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    /// `parse` plus the old `(cmd, positionals, flags)` view the
    /// pre-registry tests asserted against.
    fn parse_args(
        args: &[String],
    ) -> Result<(String, Vec<String>, Flags)> {
        let inv = parse(args)?;
        let name = inv
            .command
            .map(|c| c.name().to_string())
            .unwrap_or_default();
        Ok((name, inv.positionals, inv.flags))
    }

    #[test]
    fn parse_args_supports_both_flag_forms() {
        let (cmd, pos, flags) =
            parse_args(&argv(&["evaluate", "--banks=8", "--org", "SMP"]))
                .unwrap();
        assert_eq!(cmd, "evaluate");
        assert!(pos.is_empty());
        assert_eq!(flags.get("banks").map(String::as_str), Some("8"));
        assert_eq!(flags.get("org").map(String::as_str), Some("SMP"));
    }

    #[test]
    fn equals_form_does_not_swallow_next_token() {
        // the pre-redesign bug: `--banks=8 --sectors 32` stored the key
        // "banks=8" and swallowed "--sectors" as its value
        let (_, _, flags) =
            parse_args(&argv(&["evaluate", "--banks=8", "--sectors", "32"]))
                .unwrap();
        assert_eq!(flags.get("banks").map(String::as_str), Some("8"));
        assert_eq!(flags.get("sectors").map(String::as_str), Some("32"));
        assert!(!flags.contains_key("banks=8"));
    }

    #[test]
    fn timeline_accepts_positionals_others_reject_them() {
        let (cmd, pos, flags) = parse_args(&argv(&[
            "timeline", "mnist", "PG-SEP", "--format", "json",
        ]))
        .unwrap();
        assert_eq!(cmd, "timeline");
        assert_eq!(pos, vec!["mnist".to_string(), "PG-SEP".to_string()]);
        assert_eq!(flags.get("format").map(String::as_str), Some("json"));
        // a third positional is one too many
        assert!(parse_args(&argv(&["timeline", "a", "b", "c"])).is_err());
        // other subcommands keep rejecting bare tokens
        assert!(parse_args(&argv(&["evaluate", "mnist"])).is_err());
    }

    #[test]
    fn unknown_flags_are_rejected_per_subcommand() {
        // flags a subcommand does not consume are errors, not ignored
        assert!(parse_args(&argv(&["analyze", "--banks", "8"])).is_err());
        assert!(parse_args(&argv(&["info", "--model", "small"])).is_err());
        assert!(parse_args(&argv(&["evaluate", "--bogus", "1"])).is_err());
        assert!(parse_args(&argv(&["help", "--format", "json"])).is_err());
        // the dse explores the dma axis itself — no --dma flag there
        assert!(parse_args(&argv(&["dse", "--dma", "serial"])).is_err());
        // ...while consumed flags pass
        assert!(parse_args(&argv(&["dse", "--threads", "2"])).is_ok());
        assert!(parse_args(&argv(&["evaluate", "--tech=22nm"])).is_ok());
        assert!(parse_args(&argv(&["evaluate", "--dma=serial"])).is_ok());
        assert!(parse_args(&argv(&["timeline", "--batch", "8"])).is_ok());
    }

    #[test]
    fn unknown_subcommands_die_at_parse_time_with_suggestion() {
        // the old parser let `capstore frobnicate --x 1` through and
        // only the dispatcher complained; now parsing itself fails
        let err = parse(&argv(&["frobnicate", "--x", "1"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown subcommand"), "{msg}");
        // a near-miss gets a registry-derived suggestion
        let err = parse(&argv(&["trafic", "--rate", "5"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("did you mean `traffic`"), "{msg}");
        // bare `capstore` (and an empty argv token) still resolve to
        // "print usage", as before the redesign
        let inv = parse(&[]).unwrap();
        assert!(inv.command.is_none());
        let inv = parse(&argv(&[""])).unwrap();
        assert!(inv.command.is_none());
        // ...but trailing args after an empty token have nothing to
        // bind to
        assert!(parse(&argv(&["", "--format", "json"])).is_err());
    }

    #[test]
    fn traffic_flags_parse() {
        // positional shorthand + traffic knobs parse
        let (cmd, pos, flags) = parse_args(&argv(&[
            "traffic", "mnist", "PG-SEP", "--rate", "500", "--seed=7",
        ]))
        .unwrap();
        assert_eq!(cmd, "traffic");
        assert_eq!(pos.len(), 2);
        assert_eq!(flags.get("rate").map(String::as_str), Some("500"));
        assert!(
            parse_args(&argv(&["traffic", "--rates", "50,5000"])).is_ok()
        );
        // traffic knobs stay off the other subcommands
        assert!(parse_args(&argv(&["evaluate", "--rate", "5"])).is_err());
        assert!(parse_args(&argv(&["dse", "--rates", "5"])).is_err());
        // --batch would be silently ignored by the simulator's own
        // batcher, so traffic rejects it (use --max-batch)
        assert!(parse_args(&argv(&["traffic", "--batch", "4"])).is_err());
        assert!(
            parse_args(&argv(&["traffic", "--max-batch", "4"])).is_ok()
        );
    }

    #[test]
    fn flags_require_values_and_dashes() {
        assert!(parse_args(&argv(&["evaluate", "--banks"])).is_err());
        assert!(parse_args(&argv(&["evaluate", "banks", "8"])).is_err());
    }

    #[test]
    fn switch_flags_take_no_value() {
        let (_, _, flags) = parse_args(&argv(&["help", "--all"])).unwrap();
        assert!(flags.contains_key("all"));
        // `--all` does not swallow a following token as its value (the
        // token parses as a positional; the help command then rejects
        // the ambiguous --all + <cmd> combination at run time)
        let (_, pos, flags) =
            parse_args(&argv(&["help", "--all", "evaluate"])).unwrap();
        assert!(flags.contains_key("all"));
        assert_eq!(pos, vec!["evaluate".to_string()]);
        // and the `=value` form is rejected for switches
        assert!(parse_args(&argv(&["help", "--all=yes"])).is_err());
    }
}
