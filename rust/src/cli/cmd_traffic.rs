//! `capstore traffic [<net> [<org>]]` — deterministic serving
//! simulation (SLO + energy) and the serving-aware DSE re-ranking
//! (`--rates`); extracted from the old monolith with bit-identical
//! output and the same conflict-rejection order.

use crate::coordinator::BatchPolicy;
use crate::dse::Explorer;
use crate::faults::{FaultPlan, ResiliencePolicy};
use crate::report::Table;
use crate::scenario::{Evaluator, Scenario};
use crate::telemetry::CounterRegistry;
use crate::timeline::Timeline;
use crate::traffic::{
    rank_for_traffic_under, simulate_with, ArrivalPattern, ServiceModel,
    TrafficProfile,
};
use crate::util::json::Json;
use crate::util::units::fmt_energy_uj;
use crate::{Error, Result};

use super::context::{bad_flag, CommandContext};
use super::output::Output;
use super::spec::{self, FlagSpec};
use super::Command;

pub struct TrafficCmd;

impl Command for TrafficCmd {
    fn name(&self) -> &'static str {
        "traffic"
    }

    fn about(&self) -> &'static str {
        "deterministic serving simulation (SLO + energy), --rates DSE"
    }

    fn groups(&self) -> &'static [&'static [FlagSpec]] {
        &[
            spec::SCENARIO,
            spec::MEMORY,
            spec::TIME_UNBATCHED,
            spec::TRAFFIC,
            spec::FAULT_KNOBS,
            spec::PROFILE_ONLY,
            spec::PREFLIGHT,
        ]
    }

    fn max_positionals(&self) -> usize {
        2
    }

    fn positional_usage(&self) -> &'static str {
        "[<net> [<org>]]"
    }

    fn long_help(&self) -> &'static str {
        "Simulates a seeded request stream against the scenario on a\n\
         virtual cycle clock — same (pattern, rate, seed) in, identical\n\
         report out, byte for byte.  `--rates R1,R2,...` is the\n\
         serving-aware DSE: it sweeps the scenario's (network, tech)\n\
         pair, takes the Pareto front, and re-ranks it per traffic\n\
         profile, so it rejects any pinned design-point axis the\n\
         ranking would override.\n\
         \n\
         Faults and resilience: a seeded fault plan (scenario [faults]\n\
         section, --faults file, or --wake-fail-rate) injects wake\n\
         failures, DMA degradation, thermal throttle, and queue-boundary\n\
         drops/duplicates; --queue-cap/--timeout-ms/--retry-budget/\n\
         --wake-fallback select the resilience policy.  Identity plans\n\
         reproduce the fault-free report byte for byte."
    }

    fn run(&self, ctx: &CommandContext) -> Result<Output> {
        let sc = ctx.scenario_with_positionals()?;

        // `--rates` re-ranks a Pareto front, i.e. it explores the
        // organization/geometry/dma axes itself — a pinned design point
        // would be silently overridden by the sweep, and this CLI
        // rejects rather than ignores (mirroring `capstore dse`).
        if ctx.flags.contains_key("rates") {
            if ctx.flags.contains_key("profile") {
                return Err(Error::Config(
                    "--profile reports the counters of one serving run; \
                     --rates runs a whole re-ranking sweep — drop one"
                        .into(),
                ));
            }
            if ctx.positionals.get(1).is_some() {
                return Err(Error::Config(
                    "`traffic <net> <org> --rates` pins an organization \
                     the front re-ranking sweeps over — drop the \
                     organization (the ranking tries every front point), \
                     or use --rate to simulate that single design"
                        .into(),
                ));
            }
            for pinned in ["org", "banks", "sectors", "dma", "dma-bw"] {
                if ctx.flags.contains_key(pinned) {
                    return Err(Error::Config(format!(
                        "`--rates` explores the organization/geometry/dma \
                         axes itself: --{pinned} would be silently \
                         overridden — drop it, or use --rate to simulate \
                         that single design point"
                    )));
                }
            }
            if let Some(doc) = ctx.config_doc() {
                for key in ["organization", "banks", "sectors"] {
                    if doc.get("memory", key).is_some() {
                        return Err(Error::Config(format!(
                            "`--rates` explores the organization/geometry \
                             axes itself: the --config file pins \
                             `[memory] {key}`, which the front re-ranking \
                             would override — drop it, or use --rate for \
                             a single design point"
                        )));
                    }
                }
            }
            if ctx.scenario_doc().is_some() {
                let without = ctx.scenario_without_doc()?;
                if sc.organization != without.organization
                    || sc.geometry != without.geometry
                    || sc.dma != without.dma
                {
                    return Err(Error::Config(
                        "`--rates` explores the organization/geometry/dma \
                         axes itself: the scenario file pins values the \
                         front re-ranking would override — drop those \
                         keys, or use --rate for a single design point"
                            .into(),
                    ));
                }
            }
        }

        let (profile, policy, faults, resilience) =
            resolve_serving(ctx, &sc)?;

        // static pre-flight on the fully resolved workload (flags
        // already folded into profile/faults, so the scenario doc's
        // key->location mapping no longer applies — pass no doc).  The
        // --rates path skips it: the re-ranking sweeps design axes the
        // single-scenario rules would mis-blame.
        if !ctx.flags.contains_key("rates") {
            let checked = Scenario {
                traffic: Some(profile.clone()),
                faults: (!faults.is_identity()).then(|| faults.clone()),
                ..sc.clone()
            };
            super::cmd_check::preflight(ctx, &checked, None)?;
        }

        let ev = Evaluator::new();
        if let Some(list) = ctx.flag("rates") {
            if ctx.flags.contains_key("rate") {
                return Err(Error::Config(
                    "--rate simulates one profile, --rates re-ranks the \
                     Pareto front — give one or the other"
                        .into(),
                ));
            }
            return run_rank(
                &ev, &sc, &profile, &policy, list, &faults, &resilience,
            );
        }

        let profiling = ctx.flags.contains_key("profile");
        let builds_before = Timeline::build_count();
        let svc = ServiceModel::with_faults(
            &ev,
            &sc,
            policy.max_batch,
            Some(&faults),
        )?;
        let report = simulate_with(&svc, &profile, &policy, &faults,
                                   &resilience)?;

        let mut out = Output::new();
        out.json = report.to_json(svc.clock_hz);

        out.text(format!("scenario: {}", sc.label()));
        out.text(format!("traffic:  {}", profile.label()));
        out.text(format!(
            "\narrivals {}  served {}  queued {}  in {} batches \
             (mean occupancy {:.2})",
            report.arrivals,
            report.served,
            report.queued,
            report.batches,
            report.mean_occupancy(),
        ));
        out.text(format!(
            "throughput {:.1} inf/s over a {:.3}s window \
             (busy {:.1}%)",
            report.throughput_per_sec(svc.clock_hz),
            profile.duration_secs,
            100.0 * report.busy_cycles as f64
                / report.horizon_cycles.max(1) as f64,
        ));
        if let Some(s) = &report.latency_ms {
            out.text(format!(
                "latency ms: p50 {:.3}  p95 {:.3}  p99 {:.3}  \
                 max {:.3}",
                s.median, s.p95, s.p99, s.max
            ));
        }
        if !report.latency_cycles_hist.is_empty() {
            out.text(format!(
                "latency cycles: {}",
                report.latency_cycles_hist.render_line(),
            ));
        }
        out.text(format!(
            "SLO {} ms: {} violations ({:.2}% of served)",
            profile.slo_ms,
            report.slo_violations,
            100.0 * report.slo_violation_fraction(),
        ));
        match report.break_even_cycles {
            Some(be) => out.text(format!(
                "idle gating: {} cold starts, {} warm starts \
                 (break-even {} cycles)",
                report.cold_starts, report.warm_starts, be
            )),
            None => out.text(
                "idle gating: organization is ungated — memory \
                 leaks at full power between batches",
            ),
        };
        out.text(format!(
            "energy: batches {} + idle {} - warm saving {} = {} \
             ({:.3} µJ/inference)",
            fmt_energy_uj(report.batch_pj),
            fmt_energy_uj(report.idle_pj),
            fmt_energy_uj(report.warm_saving_pj),
            fmt_energy_uj(report.total_pj()),
            report.energy_uj_per_inference(),
        ));
        out.text(format!(
            "backlog: peak {} requests ({} staged bytes)",
            report.peak_queue_depth, report.peak_queue_bytes,
        ));
        if report.resilience_active {
            let s = &report.resilience;
            out.text(format!(
                "\nfaults:   {}",
                report.faults_label.as_deref().unwrap_or("no faults"),
            ));
            out.text(format!(
                "queue boundary: {} dropped  {} duplicated  {} shed  \
                 {} timed out  {} retried",
                s.dropped, s.duplicated, s.shed, s.timed_out, s.retried,
            ));
            out.text(format!(
                "wakes: {} attempts, {} failed ({} extra); \
                 dma-degraded {} batches, throttled {} ({} extra)",
                s.wake_attempts,
                s.wake_failures,
                fmt_energy_uj(s.wake_retry_pj),
                s.dma_degraded_batches,
                s.throttled_batches,
                fmt_energy_uj(s.throttle_extra_pj),
            ));
            match s.fallback_at_cycle {
                Some(c) => out.text(format!(
                    "all-on fallback engaged at cycle {c} — gating \
                     disabled for the rest of the run"
                )),
                None => out.text("all-on fallback: never engaged"),
            };
        }
        if profiling {
            // deterministic counters: the conservation-law buckets and
            // fault tallies of this run, plus how many Timeline IRs the
            // command built (service-model construction only — the
            // event loop itself builds zero)
            let mut counters =
                CounterRegistry::from_traffic_report(&report);
            counters.set(
                "timeline.builds",
                Timeline::build_count() - builds_before,
            );
            let snap = counters.snapshot();
            if let Json::Obj(m) = &mut out.json {
                m.insert(
                    "profile".into(),
                    Json::obj(vec![("counters", snap.to_json())]),
                );
            }
            out.blank();
            out.table(snap.table("profile — deterministic counters"));
        }
        Ok(out)
    }
}

/// Resolve the four serving knobs — workload profile, batching
/// triggers, fault plan, resilience policy — from the scenario under
/// the flags, with validation.  Shared with `capstore trace --traffic`
/// so a traced run resolves its inputs exactly like an untraced one.
pub(super) fn resolve_serving(
    ctx: &CommandContext,
    sc: &Scenario,
) -> Result<(TrafficProfile, BatchPolicy, FaultPlan, ResiliencePolicy)> {
    let rc = ctx.run_config();

    // workload: scenario [traffic] section (if any) under the flags
    let mut profile = sc.traffic.clone().unwrap_or_default();
    if let Some(v) = ctx.flag("pattern") {
        profile.pattern = ArrivalPattern::by_name(v).ok_or_else(|| {
            Error::Config(format!(
                "--pattern: want one of {}, got {v:?}",
                ArrivalPattern::names().join("|")
            ))
        })?;
    }
    if let Some(v) = ctx.parsed("rate")? {
        profile.rate_per_sec = v;
    }
    if let Some(v) = ctx.parsed("seed")? {
        profile.seed = v;
    }
    if let Some(v) = ctx.parsed("duration")? {
        profile.duration_secs = v;
    }
    if let Some(v) = ctx.parsed("slo-ms")? {
        profile.slo_ms = v;
    }
    profile.validate()?;

    // batching triggers: run-config [server] knobs under the flags
    let mut policy =
        BatchPolicy { max_batch: rc.max_batch, max_wait: rc.max_wait };
    if let Some(v) = ctx.parsed("max-batch")? {
        policy.max_batch = v;
        if policy.max_batch == 0 {
            return Err(Error::Config("--max-batch must be > 0".into()));
        }
    }
    if let Some(ms) = ctx.parsed::<f64>("max-wait-ms")? {
        if !(ms.is_finite() && ms >= 0.0) {
            return Err(Error::Config("--max-wait-ms must be >= 0".into()));
        }
        policy.max_wait = std::time::Duration::from_secs_f64(ms / 1.0e3);
    }

    // fault plan: scenario [faults] section, replaced by a --faults
    // file, overridden field-wise by the dedicated flags
    let mut faults = sc.faults.clone().unwrap_or_else(FaultPlan::none);
    if let Some(path) = ctx.flag("faults") {
        faults = FaultPlan::load(path)?;
    }
    if let Some(v) = ctx.parsed::<f64>("wake-fail-rate")? {
        faults.wake_fail_rate = v;
    }
    faults.validate()?;

    // resilience policy: flags only (the policy is an operator
    // choice, not a property of the design under test)
    let mut resilience = ResiliencePolicy::none();
    if let Some(v) = ctx.parsed::<u64>("queue-cap")? {
        if v == 0 {
            return Err(Error::Config(
                "--queue-cap must be > 0 (0 would shed everything)".into(),
            ));
        }
        resilience.queue_cap = Some(v);
    }
    if let Some(v) = ctx.parsed::<f64>("timeout-ms")? {
        resilience.timeout_ms = Some(v);
    }
    if let Some(v) = ctx.parsed::<u32>("retry-budget")? {
        resilience.retry_budget = v;
        // a retry budget needs a timeout to act on; default to the
        // SLO — a request that has already missed its deadline is
        // the one worth re-queueing fresh
        if v > 0 && resilience.timeout_ms.is_none() {
            resilience.timeout_ms = Some(profile.slo_ms);
        }
    }
    if let Some(v) = ctx.parsed::<f64>("wake-fallback")? {
        resilience.wake_fail_fallback = Some(v);
    }
    resilience.validate()?;

    Ok((profile, policy, faults, resilience))
}

/// `capstore traffic --rates R1,R2,...`: the serving-aware DSE.  Sweep
/// the scenario's (network, tech) pair, take the Pareto front, and
/// re-rank it per traffic profile — the winner moves with the load.
#[allow(clippy::too_many_arguments)]
fn run_rank(
    ev: &Evaluator,
    sc: &Scenario,
    profile: &TrafficProfile,
    policy: &BatchPolicy,
    rates: &str,
    faults: &FaultPlan,
    resilience: &ResiliencePolicy,
) -> Result<Output> {
    let rates: Vec<f64> = rates
        .split(',')
        .map(|r| {
            r.trim()
                .parse::<f64>()
                .map_err(|_| bad_flag("rates", r))
                .and_then(|v| {
                    if v.is_finite() && v > 0.0 {
                        Ok(v)
                    } else {
                        Err(bad_flag("rates", r))
                    }
                })
        })
        .collect::<Result<_>>()?;
    if rates.is_empty() {
        return Err(Error::Config(
            "--rates needs at least one rate".into(),
        ));
    }

    let mut ex = Explorer::new(sc.network.clone());
    ex.model.tech = sc.tech.technology();
    let points = ex.sweep()?;
    let front = Explorer::pareto(&points);
    let profiles: Vec<TrafficProfile> = rates
        .iter()
        .map(|&r| TrafficProfile { rate_per_sec: r, ..profile.clone() })
        .collect();
    let winners = rank_for_traffic_under(
        ev, sc, &front, &profiles, policy, faults, resilience,
    )?;

    let mut t = Table::new(
        "serving-aware DSE — best front point per traffic profile",
        &["rate/s", "org", "banks", "sectors", "dma", "occup", "p99 ms",
          "viol%", "cold", "µJ/inf", "slo"],
    );
    for w in &winners {
        let p99 = w
            .report
            .latency_ms
            .as_ref()
            .map(|s| format!("{:.3}", s.p99))
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            format!("{}", w.profile.rate_per_sec),
            w.point.organization.label().into(),
            w.point.banks.to_string(),
            w.point.sectors.to_string(),
            w.point.dma.model.label().into(),
            format!("{:.2}", w.report.mean_occupancy()),
            p99,
            format!("{:.2}", 100.0 * w.report.slo_violation_fraction()),
            w.report.cold_starts.to_string(),
            format!("{:.3}", w.report.energy_uj_per_inference()),
            if w.feasible { "ok" } else { "MISS" }.to_string(),
        ]);
    }

    let mut out = Output::new();
    out.json = Json::obj(vec![
        ("network", Json::Str(sc.network.name.to_string())),
        ("tech", Json::Str(sc.tech.label().to_string())),
        ("front_points", Json::Num(front.len() as f64)),
        ("winners", t.to_json()),
    ]);

    out.text(format!(
        "scenario: {} | pattern {} seed {} duration {}s slo {}ms",
        sc.label(),
        profile.pattern.label(),
        profile.seed,
        profile.duration_secs,
        profile.slo_ms,
    ));
    if !faults.is_identity() || resilience.is_active() {
        out.text(format!("faults:   {}", faults.label()));
    }
    out.text(format!(
        "front: {} Pareto points of a {}-point sweep\n",
        front.len(),
        points.len()
    ));
    out.table(t);
    let shifted =
        winners.windows(2).any(|w| !w[0].point.bit_eq(&w[1].point));
    if shifted {
        out.text(
            "\nthe energy-optimal design point shifts with the \
             traffic profile",
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::Flags;
    use super::*;

    fn run_traffic(
        positionals: Vec<String>,
        flags: Flags,
    ) -> Result<Output> {
        let ctx = CommandContext::new("traffic", positionals, flags)?;
        TrafficCmd.run(&ctx)
    }

    #[test]
    fn traffic_flag_conflicts_are_rejected() {
        // --rate and --rates are mutually exclusive (checked in the
        // command, after parsing)
        let mut flags = Flags::new();
        flags.insert("rate".into(), "100".into());
        flags.insert("rates".into(), "100,200".into());
        assert!(run_traffic(Vec::new(), flags).is_err());
        // bad pattern is rejected
        let mut flags = Flags::new();
        flags.insert("pattern".into(), "fractal".into());
        assert!(run_traffic(Vec::new(), flags).is_err());
        // --rates explores the design-point axes itself: a pinned
        // organization/geometry/dma (flag or positional) is rejected,
        // never silently overridden by the sweep
        for (key, value) in [
            ("org", "SMP"),
            ("banks", "4"),
            ("sectors", "8"),
            ("dma", "serial"),
            ("dma-bw", "32"),
        ] {
            let mut flags = Flags::new();
            flags.insert("rates".into(), "100,200".into());
            flags.insert(key.into(), value.into());
            assert!(
                run_traffic(Vec::new(), flags).is_err(),
                "--rates accepted pinned --{key}"
            );
        }
        let mut flags = Flags::new();
        flags.insert("rates".into(), "100,200".into());
        assert!(run_traffic(
            vec!["mnist".into(), "PG-SEP".into()],
            flags
        )
        .is_err());
    }

    #[test]
    fn fault_flags_are_validated() {
        // a wake-fail probability outside [0, 1) is a config error
        for bad in ["1.5", "-0.1", "nan"] {
            let mut flags = Flags::new();
            flags.insert("rate".into(), "100".into());
            flags.insert("wake-fail-rate".into(), bad.into());
            assert!(
                run_traffic(Vec::new(), flags).is_err(),
                "accepted wake-fail-rate {bad}"
            );
        }
        // a zero queue cap would shed everything
        let mut flags = Flags::new();
        flags.insert("queue-cap".into(), "0".into());
        assert!(run_traffic(Vec::new(), flags).is_err());
        // a fallback threshold must be in (0, 1]
        let mut flags = Flags::new();
        flags.insert("wake-fallback".into(), "0".into());
        assert!(run_traffic(Vec::new(), flags).is_err());
        // a missing fault-plan file is an error, not a silent identity
        let mut flags = Flags::new();
        flags.insert("faults".into(), "/nonexistent/plan.toml".into());
        assert!(run_traffic(Vec::new(), flags).is_err());
    }

    #[test]
    fn retry_budget_defaults_its_timeout_to_the_slo() {
        // --retry-budget alone must not be silently inert: the command
        // pairs it with a timeout at the SLO, so the run reports an
        // active resilience section
        let mut flags = Flags::new();
        flags.insert("rate".into(), "2000".into());
        flags.insert("duration".into(), "0.02".into());
        flags.insert("retry-budget".into(), "1".into());
        flags.insert("format".into(), "json".into());
        let out = run_traffic(Vec::new(), flags).unwrap();
        assert!(
            out.json.render().contains("\"resilience\""),
            "retry-budget alone produced no resilience section"
        );
    }

    #[test]
    fn wake_fail_rate_flag_changes_the_report() {
        let base = |wake: Option<&str>| {
            let mut flags = Flags::new();
            flags.insert("rate".into(), "200".into());
            flags.insert("duration".into(), "0.05".into());
            flags.insert("max-batch".into(), "1".into());
            flags.insert("format".into(), "json".into());
            if let Some(w) = wake {
                flags.insert("wake-fail-rate".into(), w.into());
            }
            run_traffic(Vec::new(), flags).unwrap().json.render()
        };
        let clean = base(None);
        let faulty = base(Some("0.9"));
        assert!(!clean.contains("\"resilience\""));
        assert!(faulty.contains("\"resilience\""));
        assert!(faulty.contains("wake_failures"));
        assert_ne!(clean, faulty);
        // determinism: the same faulty invocation is byte-identical
        assert_eq!(faulty, base(Some("0.9")));
    }
}
