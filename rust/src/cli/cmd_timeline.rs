//! `capstore timeline [<net> [<org>]]` — render the cycle-resolved
//! Timeline IR: op intervals, per-macro gating segments, DMA stalls;
//! extracted from the old monolith with bit-identical output.

use crate::report::Table;
use crate::scenario::Evaluator;
use crate::util::json::Json;
use crate::util::units::{fmt_energy_uj, fmt_si};
use crate::Result;

use super::context::CommandContext;
use super::output::Output;
use super::spec::{self, FlagSpec};
use super::Command;

pub struct TimelineCmd;

impl Command for TimelineCmd {
    fn name(&self) -> &'static str {
        "timeline"
    }

    fn about(&self) -> &'static str {
        "render the cycle-resolved Timeline IR"
    }

    fn groups(&self) -> &'static [&'static [FlagSpec]] {
        &[spec::SCENARIO, spec::MEMORY, spec::TIME]
    }

    fn max_positionals(&self) -> usize {
        2
    }

    fn positional_usage(&self) -> &'static str {
        "[<net> [<org>]]"
    }

    fn long_help(&self) -> &'static str {
        "Renders op intervals with per-op utilization over time, merged\n\
         per-macro gating segments, DMA stalls (when transfers are not\n\
         hidden), and the batch/pipelining summary.  A positional given\n\
         together with its flag form (`timeline small --model mnist`)\n\
         is a conflict and errors out."
    }

    fn run(&self, ctx: &CommandContext) -> Result<Output> {
        let sc = ctx.scenario_with_positionals()?;

        let ev = Evaluator::new();
        let e = ev.evaluate(&sc)?;
        let tl = e.timeline();

        // op intervals + per-op utilization (Fig 4a/4c over time)
        let mut headers: Vec<String> =
            ["#", "inf", "op", "start", "end", "util%"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        for m in &tl.macros {
            headers.push(format!("{} ON", m.label));
        }
        let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut t_ops =
            Table::new("Timeline — op intervals and ON sectors", &hrefs);
        for row in e.utilization() {
            let mut cells = vec![
                row.op_index.to_string(),
                row.inference.to_string(),
                row.kind.label().to_string(),
                row.interval.start.to_string(),
                row.interval.end.to_string(),
                format!("{:.1}", 100.0 * row.on_fraction),
            ];
            for (m, &on) in tl.macros.iter().zip(&row.sectors_on) {
                cells.push(format!("{on}/{}", m.total_sectors));
            }
            t_ops.row(cells);
        }

        // per-macro gating segments (merged constant-ON runs)
        let mut t_seg = Table::new(
            "Timeline — per-macro gating segments",
            &["macro", "start", "end", "cycles", "ON sectors", "state"],
        );
        for (mi, m) in tl.macros.iter().enumerate() {
            for (iv, on) in tl.macro_segments(mi) {
                let state = if on == 0 {
                    "OFF"
                } else if on < m.total_sectors {
                    "partial"
                } else {
                    "ON"
                };
                t_seg.row(vec![
                    m.label.to_string(),
                    iv.start.to_string(),
                    iv.end.to_string(),
                    fmt_si(iv.cycles()),
                    format!("{on}/{}", m.total_sectors),
                    state.to_string(),
                ]);
            }
        }

        // DMA stalls (only present when transfers are not hidden)
        let mut t_stall =
            Table::new("Timeline — DMA stalls", &["start", "end", "cycles"]);
        for s in &tl.stalls {
            t_stall.row(vec![
                s.interval.start.to_string(),
                s.interval.end.to_string(),
                fmt_si(s.interval.cycles()),
            ]);
        }

        let mut out = Output::new();
        out.json = Json::obj(vec![
            ("scenario", Json::Str(sc.label())),
            ("ops", t_ops.to_json()),
            ("gating_segments", t_seg.to_json()),
            ("stalls", t_stall.to_json()),
            ("total_cycles", Json::Num(tl.total_cycles as f64)),
            ("stall_cycles", Json::Num(tl.stall_cycles() as f64)),
            ("transitions", Json::Num(tl.transitions() as f64)),
            ("wakeup_pj", Json::Num(tl.wakeup_pj())),
            ("static_pj", Json::Num(tl.static_pj())),
            ("batch_pj", Json::Num(e.batch_pj())),
            ("pipeline_saving_pj", Json::Num(e.batch.pipeline_saving_pj)),
        ]);

        out.text(format!("scenario: {}", sc.label()));
        out.table(t_ops);
        out.blank();
        out.table(t_seg);
        if !tl.stalls.is_empty() {
            out.blank();
            out.table(t_stall);
        }
        out.text(format!(
            "\nmakespan: {} cycles ({:.3} ms), batch {}, stalls {}",
            fmt_si(tl.total_cycles),
            tl.latency_secs() * 1.0e3,
            sc.batch,
            fmt_si(tl.stall_cycles()),
        ));
        out.text(format!(
            "gating: {} transitions, wakeup {}, event static {}",
            tl.transitions(),
            fmt_energy_uj(tl.wakeup_pj()),
            fmt_energy_uj(tl.static_pj()),
        ));
        out.text(format!(
            "batch energy: {} ({} saved by pipelining)",
            fmt_energy_uj(e.batch_pj()),
            fmt_energy_uj(e.batch.pipeline_saving_pj),
        ));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::Flags;
    use super::*;

    #[test]
    fn timeline_positionals_conflict_with_flags() {
        let mut flags = Flags::new();
        flags.insert("model".into(), "mnist".into());
        let ctx =
            CommandContext::new("timeline", vec!["small".into()], flags)
                .unwrap();
        assert!(TimelineCmd.run(&ctx).is_err());
        let mut flags = Flags::new();
        flags.insert("org".into(), "SMP".into());
        let ctx = CommandContext::new(
            "timeline",
            vec!["mnist".into(), "PG-SEP".into()],
            flags,
        )
        .unwrap();
        assert!(TimelineCmd.run(&ctx).is_err());
    }
}
