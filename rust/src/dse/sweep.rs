//! Sweep execution: memoized SRAM costs, the deduplicated
//! [`CostTable`] kernel with chunked multi-threaded pricing, streaming
//! front maintenance with dominance-aware branch-and-bound, and the
//! enlarged multi-network / multi-technology "grand" sweep.
//!
//! Design rules:
//!
//! * **Determinism** — the parallel path writes each design point into a
//!   pre-allocated slot indexed by its enumeration position, so output
//!   order (and every f64 bit) is identical to the serial path.  A test
//!   in `tests/dse_parallel.rs` pins this.  The pruning round schedule
//!   ([`PRUNE_ROUND_GEOMETRIES`]) is a fixed constant, never a function
//!   of the worker count, so prune decisions (and the statistics) are
//!   thread-count independent too.
//! * **No new dependencies** — `std::thread::scope` only; no rayon.
//! * **Memoization is exact** — [`CostCache`] keys on the full SRAM
//!   geometry *and* every technology constant (by f64 bit pattern), and
//!   `memsim::cacti::evaluate` is a pure function, so a cache hit returns
//!   the exact floats a fresh evaluation would.
//! * **No locks inside workers** — the hot path prices against the
//!   immutable [`CostTable`]; the `Mutex` in [`CostCache`] is only
//!   taken while *solving distinct geometries* (and on the
//!   [`run_legacy`] baseline path kept for the `dse_scale` bench).

use std::collections::HashMap; // lint:allow(determinism) value cache, never iterated
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::analysis::bounds::{dma_latency_cycles, LatencyBound};
use crate::analysis::breakdown::EnergyModel;
use crate::capsnet::CapsNetConfig;
use crate::capstore::arch::{CapStoreArch, Organization};
use crate::dse::context::SweepContext;
use crate::dse::skyline::Skyline;
use crate::dse::table::CostTable;
use crate::dse::{DesignPoint, SweepSpace};
use crate::error::Result;
use crate::memsim::cacti::{self, SramConfig, SramCosts, Technology};
use crate::timeline::{self, DmaPolicy};

// ---------------------------------------------------------------------
// SRAM cost cache
// ---------------------------------------------------------------------

/// Technology constants as a hashable key (f64 bit patterns — exact,
/// no epsilon games; two techs are "the same" iff every constant is).
/// The exhaustive destructuring (no `..`) turns a new `Technology` field
/// into a compile error here, so it can never be silently left out of
/// the cache key.
fn tech_bits(t: &Technology) -> [u64; 9] {
    let Technology {
        cell_mm2_per_byte,
        bank_periphery_mm2,
        access_fixed_pj,
        access_bitline_pj_per_sqrt_byte,
        write_premium,
        port_energy_factor,
        port_area_factor,
        leakage_mw_per_mm2,
        htree_pj_per_byte,
    } = t;
    [
        cell_mm2_per_byte.to_bits(),
        bank_periphery_mm2.to_bits(),
        access_fixed_pj.to_bits(),
        access_bitline_pj_per_sqrt_byte.to_bits(),
        write_premium.to_bits(),
        port_energy_factor.to_bits(),
        port_area_factor.to_bits(),
        leakage_mw_per_mm2.to_bits(),
        htree_pj_per_byte.to_bits(),
    ]
}

/// Memoizing wrapper around [`cacti::evaluate`], keyed on
/// `(size, banks, sectors, ports, technology)`.  Identical geometries
/// recur constantly across a sweep — every organization shares bank/
/// sector axes, and HY's small dedicated macros collapse to a handful of
/// rounded sizes — so the sweep solves each distinct geometry once.
///
/// Thread-safe: one cache is shared by all sweep workers.
#[derive(Default)]
pub struct CostCache {
    // point lookups only: the cache is never iterated, so hash order
    // cannot leak into any result
    map: Mutex<HashMap<(SramConfig, [u64; 9]), SramCosts>>, // lint:allow(determinism)
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CostCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluate with memoization.  Bit-identical to a fresh
    /// [`cacti::evaluate`] call: the model is a pure function of the key.
    ///
    /// One short lock per call; the analytical model is a few dozen
    /// flops, so holding the lock across a miss is cheaper than locking
    /// twice and risking duplicate computes.
    pub fn evaluate(
        &self,
        sram: &SramConfig,
        tech: &Technology,
    ) -> Result<SramCosts> {
        let key = (sram.clone(), tech_bits(tech));
        let mut map = self.map.lock().unwrap();
        if let Some(hit) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(hit.clone());
        }
        let costs = cacti::evaluate(sram, tech)?;
        map.insert(key, costs.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(costs)
    }

    /// Distinct geometries solved so far.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------
// Point enumeration + evaluation
// ---------------------------------------------------------------------

/// One un-evaluated coordinate of the sweep space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PointSpec {
    pub organization: Organization,
    pub banks: u64,
    pub sectors: u64,
    pub dma: DmaPolicy,
}

/// Enumerate a space in canonical (organization, banks, sectors, dma)
/// order.  Ungated organizations ignore the sector axis (deduplicated
/// to one point per bank count), matching the legacy serial sweep
/// exactly; the DMA axis is innermost, mirroring
/// `scenario::ScenarioSet::scenarios`.
pub fn enumerate(space: &SweepSpace) -> Vec<PointSpec> {
    let mut specs = Vec::new();
    for &org in &space.organizations {
        for &banks in &space.banks {
            let sector_axis: &[u64] =
                if org.gated() { &space.sectors } else { &[1] };
            for &sectors in sector_axis {
                for &dma in &space.dma {
                    specs.push(PointSpec {
                        organization: org,
                        banks,
                        sectors,
                        dma,
                    });
                }
            }
        }
    }
    specs
}

/// Evaluate one design point: build the architecture (through the cost
/// cache) and integrate its energy against the shared context.  The DMA
/// axis is priced with the shared O(ops)
/// [`timeline::price_design_point`] scan — the full Timeline IR is
/// never built on this hot path (the `timeline_build` bench enforces
/// it).
pub fn evaluate_point(
    model: &EnergyModel,
    ctx: &SweepContext,
    cache: &CostCache,
    spec: &PointSpec,
) -> Result<DesignPoint> {
    let arch = CapStoreArch::build_with(
        spec.organization,
        &model.req,
        spec.banks,
        spec.sectors,
        &mut |sram| cache.evaluate(sram, &model.tech),
    )?;
    let e = model.evaluate_arch_in(ctx, &arch);
    let (stall_pj, latency) = timeline::price_design_point(
        &ctx.op_kinds,
        &ctx.op_cycles,
        &ctx.op_offchip,
        ctx.clock_hz,
        &arch,
        &model.req,
        &spec.dma,
    );
    Ok(DesignPoint {
        organization: spec.organization,
        banks: spec.banks,
        sectors: spec.sectors,
        dma: spec.dma,
        onchip_energy_pj: timeline::priced_onchip_pj(e.onchip_pj, stall_pj),
        area_mm2: e.area_mm2,
        capacity_bytes: e.capacity_bytes,
        latency_cycles: latency,
    })
}

/// Resolve a thread-count request: 0 = one worker per available core,
/// and never more workers than points.
pub fn effective_threads(requested: usize, points: usize) -> usize {
    let t = if requested == 0 {
        // only the worker count (speed) depends on the machine: every
        // sweep output is slot-indexed and bit-identical across thread
        // counts (tests/dse_parallel.rs)
        std::thread::available_parallelism() // lint:allow(determinism)
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    };
    t.max(1).min(points.max(1))
}

/// Run a sweep over `specs` through the deduplicated [`CostTable`]
/// kernel: distinct geometries are solved once (in parallel), then
/// every point is priced lock-free into a pre-allocated slot indexed
/// by its enumeration position — deterministic order, bit-identical to
/// [`run_legacy`] and to the serial path.
pub fn run(
    model: &EnergyModel,
    ctx: &SweepContext,
    cache: &CostCache,
    specs: &[PointSpec],
    threads: usize,
) -> Result<Vec<DesignPoint>> {
    let table = CostTable::build(model, ctx, cache, specs, threads)?;
    let n = specs.len();
    let threads = effective_threads(threads, n);
    if threads <= 1 || n <= 1 {
        return Ok(specs
            .iter()
            .enumerate()
            .map(|(i, s)| table.price(i, s))
            .collect());
    }

    let chunk = n.div_ceil(threads);
    let mut slots: Vec<Option<DesignPoint>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (ci, (spec_chunk, out_chunk)) in
            specs.chunks(chunk).zip(slots.chunks_mut(chunk)).enumerate()
        {
            let base = ci * chunk;
            let table = &table;
            scope.spawn(move || {
                for (k, (spec, slot)) in
                    spec_chunk.iter().zip(out_chunk.iter_mut()).enumerate()
                {
                    *slot = Some(table.price(base + k, spec));
                }
            });
        }
    });
    Ok(slots
        .into_iter()
        .map(|s| s.expect("worker filled every slot"))
        .collect())
}

/// The PR7 engine: per-point architecture rebuild + energy integration
/// through the mutex-guarded [`CostCache`], chunked workers.  Kept as
/// the speedup baseline for `benches/dse_scale.rs` and as an equality
/// oracle — [`run`] must stay bit-identical to it.
pub fn run_legacy(
    model: &EnergyModel,
    ctx: &SweepContext,
    cache: &CostCache,
    specs: &[PointSpec],
    threads: usize,
) -> Result<Vec<DesignPoint>> {
    let n = specs.len();
    let threads = effective_threads(threads, n);
    if threads <= 1 || n <= 1 {
        return specs
            .iter()
            .map(|s| evaluate_point(model, ctx, cache, s))
            .collect();
    }

    let chunk = n.div_ceil(threads);
    let mut slots: Vec<Option<Result<DesignPoint>>> =
        (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (spec_chunk, out_chunk) in
            specs.chunks(chunk).zip(slots.chunks_mut(chunk))
        {
            scope.spawn(move || {
                for (spec, slot) in
                    spec_chunk.iter().zip(out_chunk.iter_mut())
                {
                    *slot = Some(evaluate_point(model, ctx, cache, spec));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("worker filled every slot"))
        .collect()
}

// ---------------------------------------------------------------------
// Streaming front + dominance-aware branch-and-bound
// ---------------------------------------------------------------------

/// Deterministic counters of one front-streaming sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SweepStats {
    /// Design points the space enumerated.
    pub specs: u64,
    /// Distinct (organization, banks, sectors) geometries solved.
    pub geometries: u64,
    /// Distinct DMA policies placed.
    pub dma_policies: u64,
    /// Geometry subtrees rejected against the incumbent front.
    pub pruned_geometries: u64,
    /// Points skipped by pruning (0 with pruning off).
    pub pruned_points: u64,
    /// Points actually priced; `pruned_points + priced_points == specs`.
    pub priced_points: u64,
    /// Size of the surviving Pareto front.
    pub front_len: u64,
}

/// Geometries admitted per pruning round.  A fixed constant — NOT a
/// function of the worker count — so the round schedule, the incumbent
/// front at every admission test, and therefore the prune statistics
/// are bit-identical across `--threads {1, 4, 0}` (pinned in
/// `tests/dse_parallel.rs`).
const PRUNE_ROUND_GEOMETRIES: usize = 64;

/// Sweep `specs` but return only the Pareto front (plus statistics),
/// maintained incrementally by the [`Skyline`] — never materializing
/// the full point list, which is what lets the ≥1M-point huge space
/// run in bounded memory.
///
/// With `prune_dominated`, whole geometry subtrees are rejected before
/// pricing whenever the incumbent front strictly dominates their
/// admissible [`CostTable::bound`].  Rounds of
/// [`PRUNE_ROUND_GEOMETRIES`] geometries alternate a serial admission
/// test, parallel pricing of the admitted subtrees, and serial skyline
/// insertion; because a pruned subtree is strictly dominated by an
/// already-inserted point, the final front is bit-identical — tie
/// order included — to `pareto::front` over the exhaustive sweep,
/// pruned or not.
pub fn run_front(
    model: &EnergyModel,
    ctx: &SweepContext,
    cache: &CostCache,
    specs: &[PointSpec],
    threads: usize,
    prune_dominated: bool,
) -> Result<(Vec<DesignPoint>, SweepStats)> {
    run_front_profiled(model, ctx, cache, specs, threads, prune_dominated, None)
}

/// [`run_front`] with optional per-phase profiling.  With
/// `profile: None` this IS `run_front` — no extra work, bit-identical
/// results.  With a [`SweepProfile`], each phase records its work units
/// on the profile's virtual clock: `geometry solve` (distinct
/// geometries solved into the [`CostTable`]), then per admission round
/// `admission` (geometries tested against the incumbent front),
/// `pricing` (points priced), and `skyline` (front insertions).  All
/// counts are slot-indexed/deterministic, so the profile — unlike wall
/// clock — is identical across machines and `--threads` values.
pub fn run_front_profiled(
    model: &EnergyModel,
    ctx: &SweepContext,
    cache: &CostCache,
    specs: &[PointSpec],
    threads: usize,
    prune_dominated: bool,
    mut profile: Option<&mut crate::telemetry::SweepProfile>,
) -> Result<(Vec<DesignPoint>, SweepStats)> {
    let table = CostTable::build(model, ctx, cache, specs, threads)?;
    if let Some(p) = profile.as_deref_mut() {
        p.phase("geometry solve", 0, table.num_geometries() as u64);
    }
    let mut stats = SweepStats {
        specs: specs.len() as u64,
        geometries: table.num_geometries() as u64,
        dma_policies: table.num_policies() as u64,
        ..SweepStats::default()
    };
    let mut sky = Skyline::new();
    let mut batch: Vec<u32> = Vec::new();
    let mut priced: Vec<DesignPoint> = Vec::new();
    let ngeoms = table.num_geometries();
    let mut round_start = 0;
    let mut round = 0u64;
    while round_start < ngeoms {
        round += 1;
        let round_end = (round_start + PRUNE_ROUND_GEOMETRIES).min(ngeoms);
        batch.clear();
        for gi in round_start..round_end {
            let m = table.geometry_members(gi);
            if prune_dominated && sky.prunes(&table.bound(gi)) {
                stats.pruned_geometries += 1;
                stats.pruned_points += m.len() as u64;
            } else {
                batch.extend_from_slice(m);
            }
        }
        if let Some(p) = profile.as_deref_mut() {
            p.phase("admission", round, (round_end - round_start) as u64);
        }
        price_batch(&table, specs, &batch, threads, &mut priced);
        stats.priced_points += priced.len() as u64;
        if let Some(p) = profile.as_deref_mut() {
            p.phase("pricing", round, priced.len() as u64);
            p.phase("skyline", round, batch.len() as u64);
        }
        for (&i, p) in batch.iter().zip(priced.drain(..)) {
            sky.insert(i as u64, p);
        }
        round_start = round_end;
    }
    stats.front_len = sky.len() as u64;
    Ok((sky.into_front(), stats))
}

/// Price one admission round's members in parallel, slot-indexed into
/// `out` (cleared first) in batch order.
fn price_batch(
    table: &CostTable,
    specs: &[PointSpec],
    batch: &[u32],
    threads: usize,
    out: &mut Vec<DesignPoint>,
) {
    let n = batch.len();
    out.clear();
    let threads = effective_threads(threads, n);
    if threads <= 1 || n <= 1 {
        out.extend(
            batch.iter().map(|&i| table.price(i as usize, &specs[i as usize])),
        );
        return;
    }
    let chunk = n.div_ceil(threads);
    let mut slots: Vec<Option<DesignPoint>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (idx_chunk, out_chunk) in
            batch.chunks(chunk).zip(slots.chunks_mut(chunk))
        {
            scope.spawn(move || {
                for (&i, slot) in idx_chunk.iter().zip(out_chunk.iter_mut())
                {
                    *slot = Some(table.price(i as usize, &specs[i as usize]));
                }
            });
        }
    });
    out.extend(
        slots.into_iter().map(|s| s.expect("worker filled every slot")),
    );
}

/// Filter `specs` through an admissible latency bound *before* pricing
/// anything.  A spec's static latency — the `place()` schedule at batch
/// 1 under its DMA policy — is the exact `DesignPoint::latency_cycles`
/// value [`evaluate_point`] would record, so the surviving set prices
/// to exactly the admitted subset of the full sweep, bit for bit
/// (`tests/analysis_check.rs` pins both directions).  Latency depends
/// only on the DMA coordinate, so one latency is computed per distinct
/// policy — a small linear memo, deliberately not a hash map, keeping
/// the deterministic modules free of hash-order-dependent code.
pub fn prune(
    ctx: &SweepContext,
    specs: Vec<PointSpec>,
    bound: &LatencyBound,
) -> Vec<PointSpec> {
    if bound.max_latency_cycles.is_none() {
        return specs;
    }
    let mut memo: Vec<(DmaPolicy, u64)> = Vec::new();
    specs
        .into_iter()
        .filter(|s| {
            let lat = match memo.iter().find(|(d, _)| *d == s.dma) {
                Some(&(_, l)) => l,
                None => {
                    let l = dma_latency_cycles(ctx, &s.dma, 1);
                    memo.push((s.dma, l));
                    l
                }
            };
            bound.admits(lat)
        })
        .collect()
}

/// [`run`] over the bound-admitted subset of `specs`: the seed of the
/// ROADMAP's branch-and-bound item — an inadmissible subtree is dropped
/// before its points are priced.
pub fn run_bounded(
    model: &EnergyModel,
    ctx: &SweepContext,
    cache: &CostCache,
    specs: Vec<PointSpec>,
    bound: &LatencyBound,
    threads: usize,
) -> Result<Vec<DesignPoint>> {
    let admitted = prune(ctx, specs, bound);
    run(model, ctx, cache, &admitted, threads)
}

// ---------------------------------------------------------------------
// Grand sweep: networks x technology nodes x the large space
// ---------------------------------------------------------------------

/// One evaluated point of the grand sweep, tagged with its network and
/// technology node.
#[derive(Debug, Clone)]
pub struct MultiPoint {
    pub model: &'static str,
    pub tech: &'static str,
    pub point: DesignPoint,
}

/// One (network, tech) pair's streamed Pareto front + statistics —
/// what the grand sweep returns when it does not materialize points.
#[derive(Debug, Clone)]
pub struct MultiFront {
    pub model: &'static str,
    pub tech: &'static str,
    pub front: Vec<DesignPoint>,
    pub stats: SweepStats,
}

/// The enlarged exploration: every named network config x every
/// technology node x the fine-grained [`SweepSpace::large`] axes —
/// thousands of design points where the paper's Table 1 slice had ~72.
#[derive(Debug, Clone)]
pub struct MultiSweep {
    pub models: Vec<CapsNetConfig>,
    pub techs: Vec<(&'static str, Technology)>,
    pub space: SweepSpace,
    /// Worker threads (0 = one per available core).
    pub threads: usize,
}

impl Default for MultiSweep {
    fn default() -> Self {
        MultiSweep {
            models: CapsNetConfig::all(),
            techs: Technology::nodes().to_vec(),
            space: SweepSpace::large(),
            threads: 0,
        }
    }
}

impl MultiSweep {
    /// Total points the sweep will evaluate.
    pub fn num_points(&self) -> usize {
        self.space.num_points() * self.models.len() * self.techs.len()
    }

    /// Run the whole exploration.  Delegating shim over
    /// [`crate::scenario::Evaluator::multi_sweep`]: one `SweepContext`
    /// per network — the context is technology-independent, so all tech
    /// nodes of a model share it — and one [`CostCache`] shared across
    /// everything (the key includes the technology, so nodes never
    /// cross-talk).
    pub fn run(&self) -> Result<Vec<MultiPoint>> {
        crate::scenario::Evaluator::new().multi_sweep(self)
    }

    /// Front-streaming exploration: one [`MultiFront`] per
    /// (model, tech) pair, in enumeration order, without materializing
    /// the grand point list — the only way the ≥1M-point
    /// [`SweepSpace::huge`](crate::dse::SweepSpace::huge) space stays
    /// in bounded memory.  Delegates to
    /// [`crate::scenario::Evaluator::multi_sweep_front`].
    pub fn run_front(&self, prune: bool) -> Result<Vec<MultiFront>> {
        crate::scenario::Evaluator::new().multi_sweep_front(self, prune)
    }

    /// The PR7 lock-based per-point engine over the same axes — the
    /// speedup baseline of `benches/dse_scale.rs`.
    pub fn run_legacy(&self) -> Result<Vec<MultiPoint>> {
        crate::scenario::Evaluator::new().multi_sweep_legacy(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timeline::DmaModel;

    #[test]
    fn cache_hits_on_repeat_geometry() {
        let cache = CostCache::new();
        let tech = Technology::default();
        let sram = SramConfig::new(256 << 10, 16, 8, 1);
        let a = cache.evaluate(&sram, &tech).unwrap();
        let b = cache.evaluate(&sram, &tech).unwrap();
        assert_eq!(a, b);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
        // bit-identical to the uncached model
        let fresh = cacti::evaluate(&sram, &tech).unwrap();
        assert_eq!(a.read_pj_per_byte.to_bits(), fresh.read_pj_per_byte.to_bits());
        assert_eq!(a.leakage_mw.to_bits(), fresh.leakage_mw.to_bits());
    }

    #[test]
    fn cache_distinguishes_technologies() {
        let cache = CostCache::new();
        let sram = SramConfig::new(128 << 10, 8, 4, 1);
        let t32 = Technology::default();
        let mut t_hot = Technology::default();
        t_hot.leakage_mw_per_mm2 *= 2.0;
        let a = cache.evaluate(&sram, &t32).unwrap();
        let b = cache.evaluate(&sram, &t_hot).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(b.leakage_mw > a.leakage_mw);
    }

    #[test]
    fn enumeration_dedups_ungated_sectors() {
        let space = SweepSpace {
            banks: vec![8, 16],
            sectors: vec![16, 64],
            organizations: Organization::all().to_vec(),
            dma: vec![DmaPolicy::default()],
        };
        let specs = enumerate(&space);
        // gated: 3 orgs x 2 banks x 2 sectors; ungated: 3 orgs x 2 banks
        assert_eq!(specs.len(), 18);
        assert!(specs
            .iter()
            .filter(|s| !s.organization.gated())
            .all(|s| s.sectors == 1));
    }

    #[test]
    fn enumeration_crosses_the_dma_axis() {
        let space = SweepSpace {
            banks: vec![16],
            sectors: vec![64],
            organizations: vec![Organization::Sep { gated: true }],
            dma: DmaPolicy::all_models(),
        };
        let specs = enumerate(&space);
        assert_eq!(specs.len(), 3);
        assert_eq!(specs.len(), space.num_points());
        let models: Vec<DmaModel> =
            specs.iter().map(|s| s.dma.model).collect();
        assert_eq!(
            models,
            vec![
                DmaModel::Instant,
                DmaModel::Serial,
                DmaModel::DoubleBuffered
            ]
        );
    }

    #[test]
    fn effective_threads_bounds() {
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(8, 3), 3);
        assert_eq!(effective_threads(1, 0), 1);
        assert!(effective_threads(0, 1000) >= 1);
    }

    #[test]
    fn profiled_run_front_is_transparent_and_records_phases() {
        let model = EnergyModel::new(CapsNetConfig::mnist());
        let ctx = model.context();
        let space = SweepSpace {
            banks: vec![8, 16],
            sectors: vec![16, 64],
            organizations: Organization::all().to_vec(),
            dma: vec![DmaPolicy::default()],
        };
        let specs = enumerate(&space);
        let (front_a, stats_a) =
            run_front(&model, &ctx, &CostCache::new(), &specs, 1, true)
                .unwrap();
        let mut prof = crate::telemetry::SweepProfile::new();
        let (front_b, stats_b) = run_front_profiled(
            &model,
            &ctx,
            &CostCache::new(),
            &specs,
            1,
            true,
            Some(&mut prof),
        )
        .unwrap();
        // profiling must not perturb the sweep at all
        assert_eq!(stats_a, stats_b);
        assert_eq!(front_a.len(), front_b.len());
        for (a, b) in front_a.iter().zip(&front_b) {
            assert_eq!(
                a.onchip_energy_pj.to_bits(),
                b.onchip_energy_pj.to_bits()
            );
            assert_eq!(a.latency_cycles, b.latency_cycles);
        }
        let by = prof.by_phase();
        let names: Vec<&str> = by.iter().map(|(n, _)| *n).collect();
        assert_eq!(
            names,
            vec!["geometry solve", "admission", "pricing", "skyline"]
        );
        let priced = by.iter().find(|(n, _)| *n == "pricing").unwrap().1;
        assert_eq!(priced, stats_b.priced_points);
        assert_eq!(
            by.iter().find(|(n, _)| *n == "geometry solve").unwrap().1,
            stats_b.geometries
        );
    }

    #[test]
    fn multi_sweep_space_is_thousands_of_points() {
        let ms = MultiSweep::default();
        assert!(
            ms.num_points() >= 2000,
            "grand sweep too small: {}",
            ms.num_points()
        );
    }
}
