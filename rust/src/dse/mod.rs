//! Design-space exploration (the paper's §4.2), engine edition.
//!
//! Sweeps organization × bank count × sector count (and, in the grand
//! sweep, network × technology node), evaluates each point with the full
//! energy model, and reports the Pareto front over (energy, area).  The
//! paper's Table 1 points are one slice of this space; `capstore dse`
//! prints the sweep and the winner.
//!
//! The engine is **parallel, incremental and scale-oriented**:
//!
//! * [`context::SweepContext`] — everything arch-independent (schedule,
//!   op profiles, traffic, cycle totals) computed once per network and
//!   shared immutably by every point;
//! * [`sweep::CostCache`] — memoized CACTI solutions keyed on the full
//!   SRAM geometry + technology, shared across organizations and points;
//! * [`table::CostTable`] — the contention-free cost kernel: distinct
//!   geometries deduplicated and solved once up front, then lock-free
//!   indexed pricing on the parallel hot path;
//! * [`sweep::run`] — chunked `std::thread::scope` execution with
//!   deterministic, bit-identical-to-serial output ordering;
//! * [`skyline::Skyline`] — streaming O(log n) Pareto maintenance
//!   feeding the incumbent front to the dominance-aware
//!   branch-and-bound in [`sweep::run_front`];
//! * [`pareto::front`] — O(n log n) sort-and-scan skyline for post-hoc
//!   front queries (and the oracle the streaming path is pinned to).
//!
//! `benches/dse_throughput.rs` measures the point-list stack end to
//! end; `benches/dse_scale.rs` drives the ≥1M-point
//! [`SweepSpace::huge`] space through the table kernel + streaming
//! front and gates the speedup over the PR7 per-point engine.

pub mod context;
pub mod pareto;
pub mod skyline;
pub mod sweep;
pub mod table;

use crate::analysis::breakdown::EnergyModel;
use crate::capsnet::CapsNetConfig;
use crate::capstore::arch::{CapStoreArch, Organization};
use crate::error::Result;
use crate::timeline::{self, DmaModel, DmaPolicy};

pub use context::SweepContext;
pub use skyline::Skyline;
pub use sweep::{
    CostCache, MultiFront, MultiPoint, MultiSweep, PointSpec, SweepStats,
};
pub use table::CostTable;

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub organization: Organization,
    pub banks: u64,
    pub sectors: u64,
    /// DMA/compute-overlap coordinate of the point.
    pub dma: DmaPolicy,
    /// On-chip memory energy per inference, pJ (includes the extra
    /// leakage spent during DMA stalls when transfers are not hidden).
    pub onchip_energy_pj: f64,
    pub area_mm2: f64,
    pub capacity_bytes: u64,
    /// Inference latency including DMA stalls, cycles.
    pub latency_cycles: u64,
}

impl DesignPoint {
    /// Project this point onto a full [`crate::scenario::Scenario`]:
    /// the point supplies the swept axes (organization, geometry, DMA),
    /// the base scenario everything the DSE does not sweep (network,
    /// tech node, batch, gating, traffic).  The serving-aware DSE
    /// (`crate::traffic::rank`) re-evaluates Pareto fronts through
    /// this bridge.
    pub fn scenario(&self, base: &crate::scenario::Scenario) -> crate::scenario::Scenario {
        crate::scenario::Scenario {
            organization: self.organization,
            geometry: crate::scenario::Geometry {
                banks: self.banks,
                sectors: self.sectors,
            },
            dma: self.dma,
            ..base.clone()
        }
    }

    /// Weak Pareto dominance on (energy, area): self dominates other.
    pub fn dominates(&self, other: &DesignPoint) -> bool {
        self.onchip_energy_pj <= other.onchip_energy_pj
            && self.area_mm2 <= other.area_mm2
            && (self.onchip_energy_pj < other.onchip_energy_pj
                || self.area_mm2 < other.area_mm2)
    }

    /// Exact (bit-level) equality of the f64 fields plus the discrete
    /// coordinates — the determinism contract of the parallel sweep.
    pub fn bit_eq(&self, other: &DesignPoint) -> bool {
        self.organization == other.organization
            && self.banks == other.banks
            && self.sectors == other.sectors
            && self.dma == other.dma
            && self.capacity_bytes == other.capacity_bytes
            && self.latency_cycles == other.latency_cycles
            && self.onchip_energy_pj.to_bits()
                == other.onchip_energy_pj.to_bits()
            && self.area_mm2.to_bits() == other.area_mm2.to_bits()
    }
}

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct SweepSpace {
    pub banks: Vec<u64>,
    pub sectors: Vec<u64>,
    pub organizations: Vec<Organization>,
    /// DMA/compute-overlap axis; the default space keeps the historical
    /// hidden-transfer assumption only, the large space explores all
    /// three models.
    pub dma: Vec<DmaPolicy>,
}

impl Default for SweepSpace {
    fn default() -> Self {
        SweepSpace {
            banks: vec![4, 8, 16, 32],
            sectors: vec![8, 16, 32, 64, 128],
            organizations: Organization::all().to_vec(),
            dma: vec![DmaPolicy::default()],
        }
    }
}

impl SweepSpace {
    /// The enlarged fine-grained axes: every power-of-two bank count the
    /// array can feed, intermediate sector granularities, and the three
    /// DMA-overlap models — 945 points per (network, tech) pair vs the
    /// default's ~72.
    pub fn large() -> Self {
        SweepSpace {
            banks: vec![2, 4, 8, 16, 32, 64, 128],
            sectors: vec![
                2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256,
            ],
            organizations: Organization::all().to_vec(),
            dma: DmaPolicy::all_models(),
        }
    }

    /// The million-point scale target: ≥100k points per (network,
    /// tech) pair — 24 bank counts × 48 sector granularities × 6
    /// organizations × 37 DMA policies (the hidden-transfer default
    /// plus serial/double-buffered at 18 bandwidths) = 130,536 points
    /// per pair, 1,044,288 across the grand sweep.  Built for the
    /// table-kernel + branch-and-bound path: consume it through
    /// [`Explorer::sweep_front`] / [`MultiSweep::run_front`] (which
    /// stream the front) rather than materializing the point list.
    pub fn huge() -> Self {
        let bandwidths = [
            1u64, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192,
            256, 384, 512,
        ];
        let mut dma = vec![DmaPolicy::default()];
        for model in [DmaModel::Serial, DmaModel::DoubleBuffered] {
            for &bandwidth_bytes_per_cycle in &bandwidths {
                dma.push(DmaPolicy { model, bandwidth_bytes_per_cycle });
            }
        }
        SweepSpace {
            banks: (1..=24).map(|i| 2 * i).collect(),
            sectors: (1..=48).map(|i| 4 * i).collect(),
            organizations: Organization::all().to_vec(),
            dma,
        }
    }

    /// Points this space enumerates to (closed form; gated organizations
    /// take the full sector axis, ungated collapse to one point; every
    /// point crosses the DMA axis).
    pub fn num_points(&self) -> usize {
        let gated =
            self.organizations.iter().filter(|o| o.gated()).count();
        let ungated = self.organizations.len() - gated;
        (gated * self.banks.len() * self.sectors.len()
            + ungated * self.banks.len())
            * self.dma.len()
    }

    /// Static sanity check of the space itself: an empty axis means the
    /// sweep enumerates zero points, which historically surfaced as an
    /// empty Pareto front after the full run.  Space-scoped rules live
    /// here rather than in `analysis::check` so the layering stays
    /// one-directional (`analysis` never depends on `dse`).
    pub fn check(&self) -> Vec<crate::analysis::Diagnostic> {
        use crate::analysis::Diagnostic;
        let mut out = Vec::new();
        let axes: [(&str, bool); 4] = [
            ("banks", self.banks.is_empty()),
            ("sectors", self.sectors.is_empty()),
            ("organizations", self.organizations.is_empty()),
            ("dma", self.dma.is_empty()),
        ];
        for (axis, empty) in axes {
            if empty {
                out.push(Diagnostic::new(
                    "CAP011",
                    format!("[space] {axis}"),
                    format!(
                        "sweep axis `{axis}` is empty: the space \
                         enumerates zero design points"
                    ),
                ));
            }
        }
        out
    }
}

/// Run the exploration for a network config.
pub struct Explorer {
    pub model: EnergyModel,
    pub space: SweepSpace,
    /// Worker threads for [`sweep`](Self::sweep): 0 = one per core.
    pub threads: usize,
}

impl Explorer {
    pub fn new(cfg: CapsNetConfig) -> Self {
        Explorer {
            model: EnergyModel::new(cfg),
            space: SweepSpace::default(),
            threads: 0,
        }
    }

    /// Builder-style thread override.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Evaluate every point in the space: shared context, memoized SRAM
    /// costs, chunked parallel execution (see [`sweep::run`]).  Output
    /// order — and every f64 bit — matches the serial path.
    pub fn sweep(&self) -> Result<Vec<DesignPoint>> {
        self.sweep_with_threads(self.threads)
    }

    /// [`sweep`](Self::sweep) pinned to one worker (still context-cached).
    pub fn sweep_serial(&self) -> Result<Vec<DesignPoint>> {
        self.sweep_with_threads(1)
    }

    /// [`sweep`](Self::sweep) with an explicit worker count.
    ///
    /// Delegating shim: the shared context and cost cache live in the
    /// [`crate::scenario::Evaluator`] facade, which is the one place
    /// that constructs them (outside tests and the
    /// [`sweep_baseline`](Self::sweep_baseline) oracle).
    pub fn sweep_with_threads(
        &self,
        threads: usize,
    ) -> Result<Vec<DesignPoint>> {
        crate::scenario::Evaluator::new()
            .sweep_model(&self.model, &self.space, threads)
    }

    /// [`sweep`](Self::sweep) through an admissible latency bound
    /// (see [`crate::analysis::LatencyBound`]): points whose static
    /// latency the bound rejects are pruned *before* pricing, and the
    /// result is bit-identical to filtering the full sweep after the
    /// fact.  The unconstrained bound degenerates to [`sweep`](Self::sweep).
    pub fn sweep_bounded(
        &self,
        bound: &crate::analysis::LatencyBound,
    ) -> Result<Vec<DesignPoint>> {
        crate::scenario::Evaluator::new().sweep_model_bounded(
            &self.model,
            &self.space,
            self.threads,
            bound,
        )
    }

    /// Stream the sweep through the incremental [`Skyline`] and return
    /// only the Pareto front plus deterministic [`SweepStats`] — never
    /// materializing the point list, which is what lets
    /// [`SweepSpace::huge`] run in bounded memory.  With `prune`, the
    /// dominance-aware branch-and-bound skips geometry subtrees the
    /// incumbent front already strictly dominates; the front is
    /// bit-identical either way, and identical to
    /// `Explorer::pareto(&self.sweep()?)` — pinned by
    /// `tests/dse_parallel.rs`.
    pub fn sweep_front(
        &self,
        prune: bool,
    ) -> Result<(Vec<DesignPoint>, SweepStats)> {
        self.sweep_front_profiled(prune, None)
    }

    /// [`sweep_front`](Self::sweep_front) with an optional per-phase
    /// profile (`capstore dse --profile`); `None` is the zero-cost
    /// default and the front/stats are bit-identical either way.
    pub fn sweep_front_profiled(
        &self,
        prune: bool,
        profile: Option<&mut crate::telemetry::SweepProfile>,
    ) -> Result<(Vec<DesignPoint>, SweepStats)> {
        crate::scenario::Evaluator::new().sweep_model_front_profiled(
            &self.model,
            &self.space,
            self.threads,
            prune,
            profile,
        )
    }

    /// The PR7 engine path — shared context and mutex-guarded cost
    /// cache, but per-point architecture build + energy integration —
    /// kept as the speedup baseline for `benches/dse_scale.rs` and as
    /// an equality oracle for the table kernel.
    pub fn sweep_legacy(&self) -> Result<Vec<DesignPoint>> {
        let ctx = self.model.context();
        let cache = sweep::CostCache::new();
        let specs = sweep::enumerate(&self.space);
        sweep::run_legacy(&self.model, &ctx, &cache, &specs, self.threads)
    }

    /// The pre-refactor evaluation path — per-point context rebuild, no
    /// cost cache, serial — kept as the speedup baseline for
    /// `benches/dse_throughput.rs` and the bit-identity tests.  The DMA
    /// axis goes through the same [`timeline::price_design_point`]
    /// helper the engine uses, so the identity contract extends to it.
    pub fn sweep_baseline(&self) -> Result<Vec<DesignPoint>> {
        // schedule data for the DMA pricing only; the per-point energy
        // below still rebuilds its context inside `evaluate_arch`, true
        // to the baseline's pre-refactor nature
        let ctx = self.model.context();
        let mut out = Vec::new();
        for spec in sweep::enumerate(&self.space) {
            let arch = CapStoreArch::build(
                spec.organization,
                &self.model.req,
                &self.model.tech,
                spec.banks,
                spec.sectors,
            )?;
            let e = self.model.evaluate_arch(&arch);
            let (stall_pj, latency) = timeline::price_design_point(
                &ctx.op_kinds,
                &ctx.op_cycles,
                &ctx.op_offchip,
                ctx.clock_hz,
                &arch,
                &self.model.req,
                &spec.dma,
            );
            out.push(DesignPoint {
                organization: spec.organization,
                banks: spec.banks,
                sectors: spec.sectors,
                dma: spec.dma,
                onchip_energy_pj: timeline::priced_onchip_pj(
                    e.onchip_pj,
                    stall_pj,
                ),
                area_mm2: e.area_mm2,
                capacity_bytes: e.capacity_bytes,
                latency_cycles: latency,
            });
        }
        Ok(out)
    }

    /// Non-dominated subset, sorted by energy — O(n log n) sort-and-scan
    /// (see [`pareto::front`]).
    pub fn pareto(points: &[DesignPoint]) -> Vec<DesignPoint> {
        pareto::front(points)
    }

    /// Lowest-energy point (the paper's selection criterion → PG-SEP).
    ///
    /// Ordered by `f64::total_cmp` — bit-identical to the historical
    /// `partial_cmp().unwrap()` for the non-NaN energies the models
    /// produce, but a synthetic NaN now sorts deterministically after
    /// every finite value instead of panicking (regression-tested in
    /// `pareto::tests`).
    pub fn best_energy(points: &[DesignPoint]) -> Option<&DesignPoint> {
        points
            .iter()
            .min_by(|a, b| a.onchip_energy_pj.total_cmp(&b.onchip_energy_pj))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timeline::DmaModel;

    fn quick_explorer() -> Explorer {
        let mut e = Explorer::new(CapsNetConfig::mnist());
        // keep unit tests fast: a reduced slice of the space
        e.space = SweepSpace {
            banks: vec![8, 16],
            sectors: vec![16, 64],
            organizations: Organization::all().to_vec(),
            dma: vec![DmaPolicy::default()],
        };
        e
    }

    #[test]
    fn sweep_covers_expected_points() {
        let ex = quick_explorer();
        let pts = ex.sweep().unwrap();
        // gated: 3 orgs x 2 banks x 2 sectors = 12; ungated: 3 x 2 = 6
        assert_eq!(pts.len(), 18);
        assert_eq!(ex.space.num_points(), 18);
    }

    #[test]
    fn best_energy_is_a_gated_sep() {
        let ex = quick_explorer();
        let pts = ex.sweep().unwrap();
        let best = Explorer::best_energy(&pts).unwrap();
        assert_eq!(
            best.organization.label(),
            "PG-SEP",
            "paper's §5.2 selection"
        );
    }

    #[test]
    fn pareto_front_is_nondominated_and_sorted() {
        let ex = quick_explorer();
        let pts = ex.sweep().unwrap();
        let front = Explorer::pareto(&pts);
        assert!(!front.is_empty());
        for (i, p) in front.iter().enumerate() {
            for q in &front {
                assert!(!q.dominates(p), "front point dominated");
            }
            if i > 0 {
                assert!(
                    front[i - 1].onchip_energy_pj <= p.onchip_energy_pj
                );
            }
        }
        // dominated points exist in the full sweep (front is a strict subset)
        assert!(front.len() < pts.len());
    }

    #[test]
    fn scenario_projection_round_trips_the_swept_axes() {
        use crate::scenario::Scenario;
        let ex = quick_explorer();
        let base = Scenario::default();
        for p in ex.sweep().unwrap() {
            let sc = p.scenario(&base);
            assert_eq!(sc.organization, p.organization);
            assert_eq!(sc.geometry.banks, p.banks);
            assert_eq!(sc.geometry.sectors, p.sectors);
            assert_eq!(sc.dma, p.dma);
            // un-swept axes come from the base
            assert_eq!(sc.network.name, base.network.name);
            assert_eq!(sc.tech, base.tech);
        }
    }

    #[test]
    fn dominance_is_irreflexive() {
        let ex = quick_explorer();
        let pts = ex.sweep().unwrap();
        for p in &pts {
            assert!(!p.dominates(p));
        }
    }

    #[test]
    fn engine_matches_baseline_bit_for_bit() {
        // the whole point of the refactor: context reuse + cost cache +
        // threads change nothing about the numbers
        let ex = quick_explorer();
        let baseline = ex.sweep_baseline().unwrap();
        let serial = ex.sweep_serial().unwrap();
        let parallel = ex.sweep_with_threads(4).unwrap();
        assert_eq!(baseline.len(), serial.len());
        assert_eq!(baseline.len(), parallel.len());
        for ((b, s), p) in baseline.iter().zip(&serial).zip(&parallel) {
            assert!(b.bit_eq(s), "serial diverged: {b:?} vs {s:?}");
            assert!(b.bit_eq(p), "parallel diverged: {b:?} vs {p:?}");
        }
    }

    #[test]
    fn default_space_is_clean_and_empty_axes_error() {
        assert!(SweepSpace::default().check().is_empty());
        assert!(SweepSpace::large().check().is_empty());
        let broken = SweepSpace {
            banks: Vec::new(),
            ..SweepSpace::default()
        };
        let diags = broken.check();
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, "CAP011");
        assert!(diags[0].severity.is_error());
        assert_eq!(diags[0].location, "[space] banks");
    }

    #[test]
    fn bounded_sweep_is_bit_identical_to_post_hoc_filtering() {
        use crate::analysis::LatencyBound;
        let mut ex = quick_explorer();
        // include the overlap axis so the bound actually discriminates
        ex.space.dma = DmaPolicy::all_models();
        let full = ex.sweep().unwrap();

        // unconstrained bound: exactly the full sweep
        let open = ex.sweep_bounded(&LatencyBound::unconstrained()).unwrap();
        assert_eq!(open.len(), full.len());
        for (a, b) in full.iter().zip(&open) {
            assert!(a.bit_eq(b));
        }

        // a ceiling between the instant and serial latencies: pruning
        // keeps exactly the admitted subset, in sweep order, bit for bit
        let mid = full.iter().map(|p| p.latency_cycles).min().unwrap();
        let bound = LatencyBound::at_most(mid);
        let pruned = ex.sweep_bounded(&bound).unwrap();
        let filtered: Vec<_> = full
            .iter()
            .filter(|p| bound.admits(p.latency_cycles))
            .collect();
        assert!(!pruned.is_empty());
        assert!(pruned.len() < full.len());
        assert_eq!(pruned.len(), filtered.len());
        for (a, b) in pruned.iter().zip(&filtered) {
            assert!(a.bit_eq(b), "pruned diverged: {a:?} vs {b:?}");
        }
    }

    #[test]
    fn large_space_is_fine_grained() {
        let large = SweepSpace::large();
        assert!(large.num_points() > 4 * SweepSpace::default().num_points());
        // the overlap axis triples the large space
        assert_eq!(large.dma.len(), 3);
        assert_eq!(large.num_points() % 3, 0);
    }

    #[test]
    fn huge_space_hits_the_scale_targets() {
        let huge = SweepSpace::huge();
        assert!(huge.check().is_empty());
        // ≥100k per (network, tech) pair...
        assert_eq!(huge.num_points(), 130_536);
        assert!(huge.num_points() >= 100_000);
        // ...and ≥1M across the grand sweep
        let ms = MultiSweep { space: SweepSpace::huge(), ..MultiSweep::default() };
        assert_eq!(ms.num_points(), 1_044_288);
        assert!(ms.num_points() >= 1_000_000);
        // one hidden-transfer policy + 2 models x 18 bandwidths
        assert_eq!(huge.dma.len(), 37);
    }

    #[test]
    fn streamed_front_matches_post_hoc_pareto() {
        let mut ex = quick_explorer();
        ex.space.dma = DmaPolicy::all_models();
        let post_hoc = Explorer::pareto(&ex.sweep().unwrap());
        for prune in [false, true] {
            let (front, stats) = ex.sweep_front(prune).unwrap();
            assert_eq!(front.len(), post_hoc.len());
            for (a, b) in front.iter().zip(&post_hoc) {
                assert!(a.bit_eq(b), "streamed front diverged (prune={prune})");
            }
            assert_eq!(stats.specs, ex.space.num_points() as u64);
            assert_eq!(stats.pruned_points + stats.priced_points, stats.specs);
            assert_eq!(stats.front_len, front.len() as u64);
            if !prune {
                assert_eq!(stats.pruned_points, 0);
                assert_eq!(stats.pruned_geometries, 0);
            }
        }
    }

    #[test]
    fn table_kernel_matches_the_legacy_engine_bit_for_bit() {
        let mut ex = quick_explorer();
        ex.space.dma = DmaPolicy::all_models();
        let legacy = ex.sweep_legacy().unwrap();
        let table = ex.sweep().unwrap();
        assert_eq!(legacy.len(), table.len());
        for (a, b) in legacy.iter().zip(&table) {
            assert!(a.bit_eq(b), "table kernel diverged: {a:?} vs {b:?}");
        }
    }

    #[test]
    fn dma_axis_prices_stalls_into_the_sweep() {
        let mut ex = quick_explorer();
        ex.space.dma = DmaPolicy::all_models();
        let pts = ex.sweep().unwrap();
        assert_eq!(pts.len(), ex.space.num_points());
        // baseline path agrees on the new axis too
        let baseline = ex.sweep_baseline().unwrap();
        for (b, p) in baseline.iter().zip(&pts) {
            assert!(b.bit_eq(p), "dma point diverged: {b:?} vs {p:?}");
        }
        // for a fixed geometry: hidden < double-buffered < serial on
        // both latency and energy (stall leakage is priced in)
        let find = |m: DmaModel| {
            pts.iter()
                .find(|p| {
                    p.dma.model == m
                        && p.banks == 16
                        && p.sectors == 64
                        && p.organization.label() == "PG-SEP"
                })
                .unwrap()
        };
        let instant = find(DmaModel::Instant);
        let double = find(DmaModel::DoubleBuffered);
        let serial = find(DmaModel::Serial);
        assert!(instant.latency_cycles < double.latency_cycles);
        assert!(double.latency_cycles < serial.latency_cycles);
        assert!(instant.onchip_energy_pj < double.onchip_energy_pj);
        assert!(double.onchip_energy_pj < serial.onchip_energy_pj);
        // area and capacity are time-independent
        assert_eq!(instant.area_mm2.to_bits(), serial.area_mm2.to_bits());
        assert_eq!(instant.capacity_bytes, serial.capacity_bytes);
    }
}
