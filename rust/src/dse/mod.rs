//! Design-space exploration (the paper's §4.2).
//!
//! Sweeps organization × bank count × sector count, evaluates each point
//! with the full energy model, and reports the Pareto front over
//! (energy, area).  The paper's Table 1 points are one slice of this
//! space; `capstore dse` prints the sweep and the winner.

use crate::analysis::breakdown::EnergyModel;
use crate::capsnet::CapsNetConfig;
use crate::capstore::arch::{CapStoreArch, Organization};
use crate::error::Result;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub organization: Organization,
    pub banks: u64,
    pub sectors: u64,
    pub onchip_energy_pj: f64,
    pub area_mm2: f64,
    pub capacity_bytes: u64,
}

impl DesignPoint {
    /// Weak Pareto dominance on (energy, area): self dominates other.
    pub fn dominates(&self, other: &DesignPoint) -> bool {
        self.onchip_energy_pj <= other.onchip_energy_pj
            && self.area_mm2 <= other.area_mm2
            && (self.onchip_energy_pj < other.onchip_energy_pj
                || self.area_mm2 < other.area_mm2)
    }
}

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct SweepSpace {
    pub banks: Vec<u64>,
    pub sectors: Vec<u64>,
    pub organizations: Vec<Organization>,
}

impl Default for SweepSpace {
    fn default() -> Self {
        SweepSpace {
            banks: vec![4, 8, 16, 32],
            sectors: vec![8, 16, 32, 64, 128],
            organizations: Organization::all().to_vec(),
        }
    }
}

/// Run the exploration for a network config.
pub struct Explorer {
    pub model: EnergyModel,
    pub space: SweepSpace,
}

impl Explorer {
    pub fn new(cfg: CapsNetConfig) -> Self {
        Explorer { model: EnergyModel::new(cfg), space: SweepSpace::default() }
    }

    /// Evaluate every point in the space.  Ungated organizations ignore
    /// the sector axis (deduplicated to one point per bank count).
    pub fn sweep(&self) -> Result<Vec<DesignPoint>> {
        let mut out = Vec::new();
        for &org in &self.space.organizations {
            for &banks in &self.space.banks {
                let sector_axis: &[u64] = if org.gated() {
                    &self.space.sectors
                } else {
                    &[1]
                };
                for &sectors in sector_axis {
                    let arch = CapStoreArch::build(
                        org,
                        &self.model.req,
                        &self.model.tech,
                        banks,
                        sectors,
                    )?;
                    let e = self.model.evaluate_arch(&arch);
                    out.push(DesignPoint {
                        organization: org,
                        banks,
                        sectors,
                        onchip_energy_pj: e.onchip_pj,
                        area_mm2: e.area_mm2,
                        capacity_bytes: e.capacity_bytes,
                    });
                }
            }
        }
        Ok(out)
    }

    /// Non-dominated subset, sorted by energy.
    pub fn pareto(points: &[DesignPoint]) -> Vec<DesignPoint> {
        let mut front: Vec<DesignPoint> = points
            .iter()
            .filter(|p| !points.iter().any(|q| q.dominates(p)))
            .cloned()
            .collect();
        front.sort_by(|a, b| {
            a.onchip_energy_pj.partial_cmp(&b.onchip_energy_pj).unwrap()
        });
        front
    }

    /// Lowest-energy point (the paper's selection criterion → PG-SEP).
    pub fn best_energy(points: &[DesignPoint]) -> Option<&DesignPoint> {
        points.iter().min_by(|a, b| {
            a.onchip_energy_pj.partial_cmp(&b.onchip_energy_pj).unwrap()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_explorer() -> Explorer {
        let mut e = Explorer::new(CapsNetConfig::mnist());
        // keep unit tests fast: a reduced slice of the space
        e.space = SweepSpace {
            banks: vec![8, 16],
            sectors: vec![16, 64],
            organizations: Organization::all().to_vec(),
        };
        e
    }

    #[test]
    fn sweep_covers_expected_points() {
        let ex = quick_explorer();
        let pts = ex.sweep().unwrap();
        // gated: 3 orgs x 2 banks x 2 sectors = 12; ungated: 3 x 2 = 6
        assert_eq!(pts.len(), 18);
    }

    #[test]
    fn best_energy_is_a_gated_sep() {
        let ex = quick_explorer();
        let pts = ex.sweep().unwrap();
        let best = Explorer::best_energy(&pts).unwrap();
        assert_eq!(
            best.organization.label(),
            "PG-SEP",
            "paper's §5.2 selection"
        );
    }

    #[test]
    fn pareto_front_is_nondominated_and_sorted() {
        let ex = quick_explorer();
        let pts = ex.sweep().unwrap();
        let front = Explorer::pareto(&pts);
        assert!(!front.is_empty());
        for (i, p) in front.iter().enumerate() {
            for q in &front {
                assert!(!q.dominates(p), "front point dominated");
            }
            if i > 0 {
                assert!(
                    front[i - 1].onchip_energy_pj <= p.onchip_energy_pj
                );
            }
        }
        // dominated points exist in the full sweep (front is a strict subset)
        assert!(front.len() < pts.len());
    }

    #[test]
    fn dominance_is_irreflexive() {
        let ex = quick_explorer();
        let pts = ex.sweep().unwrap();
        for p in &pts {
            assert!(!p.dominates(p));
        }
    }
}
