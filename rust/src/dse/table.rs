//! The contention-free cost kernel: a pre-enumerated, deduplicated
//! geometry table solved once up front, then pure indexed lookups on
//! the parallel hot path.
//!
//! The PR7 engine priced every [`PointSpec`] independently — each point
//! rebuilt its architecture (taking the [`CostCache`] mutex per SRAM
//! macro), re-integrated its energy and re-planned its gating, even
//! though the whole DMA axis of a geometry shares all three.  At
//! million-point scale that lock plus the redundant work dominates.
//! [`CostTable::build`] splits the sweep differently:
//!
//! 1. **Dedup pass** (serial, deterministic): assign every spec a
//!    geometry id — distinct (organization, banks, sectors), in
//!    first-seen enumeration order, found by binary search over a
//!    sorted key vector (never a hash map) — and a DMA-policy id.
//! 2. **Solve pass** (parallel, slot-indexed): one architecture build +
//!    energy integration + gating plan per *distinct geometry*.  This
//!    is the only phase that touches the [`CostCache`]; with the huge
//!    space's 37-policy DMA axis it runs ~37× fewer times than the
//!    per-point engine did.
//! 3. **Placement pass** (serial): one [`DmaPricer`] per distinct
//!    policy — the `place()` schedule is architecture-free.
//!
//! After `build`, [`CostTable::price`] is infallible and lock-free:
//! two array lookups plus the O(stalls × macros) leakage scan.  Every
//! float operation happens in the same order as the per-point path, so
//! the output is bit-identical to [`sweep::run_legacy`] — pinned by
//! `tests/dse_parallel.rs`.

use crate::analysis::bounds::ParetoBound;
use crate::analysis::breakdown::{ArchitectureEnergy, EnergyModel};
use crate::capstore::arch::{CapStoreArch, Organization};
use crate::capstore::pmu::GatingSchedule;
use crate::dse::context::SweepContext;
use crate::dse::sweep::{effective_threads, CostCache, PointSpec};
use crate::dse::DesignPoint;
use crate::error::Result;
use crate::timeline::{self, DmaPolicy, DmaPricer};

/// One solved geometry: the architecture, its context-integrated
/// energy, and its gating plan — shared by every DMA coordinate of the
/// geometry.
pub struct GeomEntry {
    pub arch: CapStoreArch,
    pub energy: ArchitectureEnergy,
    pub plan: GatingSchedule,
}

/// Total order on the geometry coordinate, for the binary-searched
/// dedup index ([`Organization`] itself deliberately has no `Ord`).
fn geom_key(s: &PointSpec) -> (u8, u64, u64) {
    let org = match s.organization {
        Organization::Smp { gated: false } => 0,
        Organization::Smp { gated: true } => 1,
        Organization::Sep { gated: false } => 2,
        Organization::Sep { gated: true } => 3,
        Organization::Hy { gated: false } => 4,
        Organization::Hy { gated: true } => 5,
    };
    (org, s.banks, s.sectors)
}

/// Structure-of-arrays cost table over one spec list.  Indices returned
/// by the accessors refer to positions in the `specs` slice passed to
/// [`build`](Self::build); callers must price against that same slice.
pub struct CostTable {
    /// Distinct geometries, in first-seen enumeration order.
    geoms: Vec<GeomEntry>,
    /// Distinct DMA policies, in first-seen enumeration order.
    pricers: Vec<DmaPricer>,
    /// spec index → geometry index.
    spec_geom: Vec<u32>,
    /// spec index → pricer index.
    spec_dma: Vec<u32>,
    /// geometry index → member spec indices, in enumeration order.
    members: Vec<Vec<u32>>,
}

impl CostTable {
    /// Dedup, solve (in parallel) and place the table for `specs`.
    pub fn build(
        model: &EnergyModel,
        ctx: &SweepContext,
        cache: &CostCache,
        specs: &[PointSpec],
        threads: usize,
    ) -> Result<CostTable> {
        let mut spec_geom = Vec::with_capacity(specs.len());
        let mut spec_dma = Vec::with_capacity(specs.len());
        let mut members: Vec<Vec<u32>> = Vec::new();
        let mut geom_specs: Vec<PointSpec> = Vec::new();
        // sorted (key, geometry id) index — binary search keeps the
        // dedup pass O(n log g) without hash-order-dependent code
        let mut seen: Vec<((u8, u64, u64), u32)> = Vec::new();
        let mut policies: Vec<DmaPolicy> = Vec::new();
        for (i, s) in specs.iter().enumerate() {
            let key = geom_key(s);
            let gi = match seen.binary_search_by(|(k, _)| k.cmp(&key)) {
                Ok(pos) => seen[pos].1,
                Err(pos) => {
                    let gi = geom_specs.len() as u32;
                    seen.insert(pos, (key, gi));
                    geom_specs.push(*s);
                    members.push(Vec::new());
                    gi
                }
            };
            spec_geom.push(gi);
            members[gi as usize].push(i as u32);
            // the policy axis is tiny (≤ a few dozen): linear scan
            let di = match policies.iter().position(|d| *d == s.dma) {
                Some(pos) => pos as u32,
                None => {
                    policies.push(s.dma);
                    (policies.len() - 1) as u32
                }
            };
            spec_dma.push(di);
        }

        let geoms = solve_geoms(model, ctx, cache, &geom_specs, threads)?;
        let pricers = policies
            .iter()
            .map(|dma| {
                DmaPricer::new(
                    &ctx.op_kinds,
                    &ctx.op_cycles,
                    &ctx.op_offchip,
                    ctx.clock_hz,
                    dma,
                )
            })
            .collect();
        Ok(CostTable { geoms, pricers, spec_geom, spec_dma, members })
    }

    pub fn num_geometries(&self) -> usize {
        self.geoms.len()
    }

    pub fn num_policies(&self) -> usize {
        self.pricers.len()
    }

    pub fn geometry(&self, gi: usize) -> &GeomEntry {
        &self.geoms[gi]
    }

    /// Enumeration positions (into the build-time spec list) of the
    /// geometry's DMA subtree.
    pub fn geometry_members(&self, gi: usize) -> &[u32] {
        &self.members[gi]
    }

    /// The admissible (energy, area) lower bound of a geometry's DMA
    /// subtree: every coordinate prices to `base onchip_pj + stall`
    /// with `stall >= 0`, and area is DMA-independent, so the
    /// hidden-transfer point *is* the subtree's componentwise minimum —
    /// the bound is tight as well as admissible.
    pub fn bound(&self, gi: usize) -> ParetoBound {
        let e = &self.geoms[gi].energy;
        ParetoBound {
            energy_lb_pj: e.onchip_pj,
            area_lb_mm2: e.area_mm2,
        }
    }

    /// Price one spec — infallible and lock-free: geometry + pricer
    /// lookups and the O(stalls × macros) leakage scan.  `i` must be
    /// `spec`'s position in the spec list the table was built from.
    pub fn price(&self, i: usize, spec: &PointSpec) -> DesignPoint {
        let g = &self.geoms[self.spec_geom[i] as usize];
        let pricer = &self.pricers[self.spec_dma[i] as usize];
        let (stall_pj, latency) = pricer.price(&g.arch, &g.plan);
        DesignPoint {
            organization: spec.organization,
            banks: spec.banks,
            sectors: spec.sectors,
            dma: spec.dma,
            onchip_energy_pj: timeline::priced_onchip_pj(
                g.energy.onchip_pj,
                stall_pj,
            ),
            area_mm2: g.energy.area_mm2,
            capacity_bytes: g.energy.capacity_bytes,
            latency_cycles: latency,
        }
    }
}

fn solve_one(
    model: &EnergyModel,
    ctx: &SweepContext,
    cache: &CostCache,
    spec: &PointSpec,
) -> Result<GeomEntry> {
    let arch = CapStoreArch::build_with(
        spec.organization,
        &model.req,
        spec.banks,
        spec.sectors,
        &mut |sram| cache.evaluate(sram, &model.tech),
    )?;
    let energy = model.evaluate_arch_in(ctx, &arch);
    let plan = GatingSchedule::plan_for(&arch, &model.req, &ctx.op_kinds);
    Ok(GeomEntry { arch, energy, plan })
}

/// Solve the distinct geometries — the same chunked, slot-indexed
/// scheduling as `sweep::run`, so results land in deterministic
/// (first-seen) order regardless of worker count.
fn solve_geoms(
    model: &EnergyModel,
    ctx: &SweepContext,
    cache: &CostCache,
    geom_specs: &[PointSpec],
    threads: usize,
) -> Result<Vec<GeomEntry>> {
    let n = geom_specs.len();
    let threads = effective_threads(threads, n);
    if threads <= 1 || n <= 1 {
        return geom_specs
            .iter()
            .map(|s| solve_one(model, ctx, cache, s))
            .collect();
    }
    let chunk = n.div_ceil(threads);
    let mut slots: Vec<Option<Result<GeomEntry>>> =
        (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (spec_chunk, out_chunk) in
            geom_specs.chunks(chunk).zip(slots.chunks_mut(chunk))
        {
            scope.spawn(move || {
                for (spec, slot) in
                    spec_chunk.iter().zip(out_chunk.iter_mut())
                {
                    *slot = Some(solve_one(model, ctx, cache, spec));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::capsnet::CapsNetConfig;
    use crate::dse::{sweep, SweepSpace};
    use crate::timeline::DmaModel;

    fn space() -> SweepSpace {
        SweepSpace {
            banks: vec![8, 16],
            sectors: vec![16, 64],
            organizations: Organization::all().to_vec(),
            dma: DmaPolicy::all_models(),
        }
    }

    #[test]
    fn dedup_counts_match_the_axes() {
        let model = EnergyModel::new(CapsNetConfig::mnist());
        let ctx = model.context();
        let cache = CostCache::new();
        let specs = sweep::enumerate(&space());
        let table =
            CostTable::build(&model, &ctx, &cache, &specs, 1).unwrap();
        // gated: 3 orgs x 2 banks x 2 sectors = 12; ungated: 3 x 2 = 6
        assert_eq!(table.num_geometries(), 18);
        assert_eq!(table.num_policies(), 3);
        assert_eq!(specs.len(), 54);
        // members partition the spec list
        let total: usize = (0..table.num_geometries())
            .map(|gi| table.geometry_members(gi).len())
            .sum();
        assert_eq!(total, specs.len());
        for gi in 0..table.num_geometries() {
            for &i in table.geometry_members(gi) {
                assert_eq!(table.spec_geom[i as usize], gi as u32);
            }
        }
    }

    #[test]
    fn table_pricing_is_bit_identical_to_the_per_point_path() {
        let model = EnergyModel::new(CapsNetConfig::mnist());
        let ctx = model.context();
        let cache = CostCache::new();
        let specs = sweep::enumerate(&space());
        let table =
            CostTable::build(&model, &ctx, &cache, &specs, 4).unwrap();
        for (i, spec) in specs.iter().enumerate() {
            let a = table.price(i, spec);
            let b =
                sweep::evaluate_point(&model, &ctx, &cache, spec).unwrap();
            assert!(a.bit_eq(&b), "spec {i} diverged:\n {a:?}\n {b:?}");
        }
    }

    #[test]
    fn bound_is_admissible_and_tight() {
        let model = EnergyModel::new(CapsNetConfig::mnist());
        let ctx = model.context();
        let cache = CostCache::new();
        let specs = sweep::enumerate(&space());
        let table =
            CostTable::build(&model, &ctx, &cache, &specs, 1).unwrap();
        for gi in 0..table.num_geometries() {
            let b = table.bound(gi);
            let mut tight_energy = false;
            for &i in table.geometry_members(gi) {
                let p = table.price(i as usize, &specs[i as usize]);
                assert!(
                    p.onchip_energy_pj >= b.energy_lb_pj,
                    "energy bound not admissible"
                );
                assert_eq!(
                    p.area_mm2.to_bits(),
                    b.area_lb_mm2.to_bits(),
                    "area is DMA-independent"
                );
                if specs[i as usize].dma.model == DmaModel::Instant {
                    // hidden transfers price exactly at the bound
                    assert_eq!(
                        p.onchip_energy_pj.to_bits(),
                        b.energy_lb_pj.to_bits()
                    );
                    tight_energy = true;
                }
            }
            assert!(tight_energy, "every geometry crosses Instant here");
        }
    }
}
