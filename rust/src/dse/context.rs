//! Re-export of the shared, immutable per-network evaluation context.
//!
//! [`SweepContext`] is defined next to its producer —
//! [`crate::analysis::breakdown::EnergyModel::context`] in
//! [`crate::analysis::context`] — so the layering stays one-directional
//! (`analysis` never depends on `dse`).  The DSE engine is its main
//! consumer, hence this re-export under the `dse` namespace.

pub use crate::analysis::context::SweepContext;
