//! Pareto skyline over (energy, area).
//!
//! The sweep's old front filter was the textbook O(n²) all-pairs
//! dominance check — fine for the original ~72 points, quadratic pain for
//! the enlarged multi-thousand-point space.  [`front`] is the standard
//! sort-and-scan 2D skyline: sort by (energy asc, area asc), then a
//! single pass keeps exactly the points no earlier point dominates.
//! O(n log n), and the output is *identical* (order included) to the
//! naive filter — a property test in `tests/dse_parallel.rs` pins that.
//!
//! Ordering uses `f64::total_cmp` throughout: bit-identical to the old
//! `partial_cmp().unwrap()` for the finite, non-negative values the
//! models produce, but a synthetic NaN coordinate now sorts under the
//! IEEE total order instead of panicking.  Under weak dominance a NaN
//! coordinate makes every comparison false, so such points are neither
//! dominated nor dominating — both fronts keep them, matching
//! [`front_naive`] exactly (regression-tested below).  Negative zeros
//! are outside the contract (the models sum non-negative terms); NaNs
//! only enter through synthetic inputs.

use std::cmp::Ordering;

use super::DesignPoint;

/// Non-dominated subset under weak (energy, area) dominance, sorted by
/// energy ascending (ties keep their original sweep order, matching the
/// stable sort of the legacy implementation; NaN energies sort last
/// among non-negative values, per `total_cmp`).
pub fn front(points: &[DesignPoint]) -> Vec<DesignPoint> {
    if points.is_empty() {
        return Vec::new();
    }

    // Sort indices by (energy, area, original index) under the IEEE
    // total order.
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        let pa = &points[a];
        let pb = &points[b];
        pa.onchip_energy_pj
            .total_cmp(&pb.onchip_energy_pj)
            .then(pa.area_mm2.total_cmp(&pb.area_mm2))
            .then(a.cmp(&b))
    });

    // Scan equal-energy groups (grouped under total_cmp, so a NaN
    // energy groups with bit-identical NaNs and the scan always
    // advances).  Within a finite group only the minimum-area points
    // can survive (any larger area is dominated by the group minimum
    // at equal energy); they survive iff no strictly-cheaper group
    // reached an area <= theirs.  NaN coordinates never dominate and
    // are never dominated, so NaN-energy groups and NaN-area members
    // survive unconditionally and leave `best_area` untouched.
    let mut keep = vec![false; points.len()];
    let mut best_area = f64::INFINITY;
    let mut i = 0;
    while i < idx.len() {
        let energy = points[idx[i]].onchip_energy_pj;
        let mut j = i;
        while j < idx.len()
            && points[idx[j]].onchip_energy_pj.total_cmp(&energy)
                == Ordering::Equal
        {
            j += 1;
        }
        if energy.is_nan() {
            for &k in &idx[i..j] {
                keep[k] = true;
            }
            i = j;
            continue;
        }
        // NaN areas sort last within the group (total_cmp), so the
        // first member holds the group's minimum area when any finite
        // area exists.
        let group_min_area = points[idx[i]].area_mm2;
        for &k in &idx[i..j] {
            if points[k].area_mm2.is_nan() {
                keep[k] = true;
            }
        }
        if !group_min_area.is_nan() && group_min_area < best_area {
            for &k in &idx[i..j] {
                if points[k].area_mm2 == group_min_area {
                    keep[k] = true;
                }
            }
            best_area = group_min_area;
        }
        i = j;
    }

    // Collect survivors in original order, then stable-sort by energy —
    // exactly what the legacy filter + stable sort produced.
    let mut out: Vec<DesignPoint> = points
        .iter()
        .enumerate()
        .filter(|(k, _)| keep[*k])
        .map(|(_, p)| p.clone())
        .collect();
    out.sort_by(|a, b| a.onchip_energy_pj.total_cmp(&b.onchip_energy_pj));
    out
}

/// The legacy O(n²) all-pairs front — kept as the oracle for the
/// property test and for auditing the fast path.
pub fn front_naive(points: &[DesignPoint]) -> Vec<DesignPoint> {
    let mut out: Vec<DesignPoint> = points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .cloned()
        .collect();
    out.sort_by(|a, b| a.onchip_energy_pj.total_cmp(&b.onchip_energy_pj));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::capstore::arch::Organization;
    use crate::testing::{check, Config};

    fn pt(e: f64, a: f64) -> DesignPoint {
        DesignPoint {
            organization: Organization::Sep { gated: true },
            banks: 16,
            sectors: 64,
            dma: crate::timeline::DmaPolicy::default(),
            onchip_energy_pj: e,
            area_mm2: a,
            capacity_bytes: 0,
            latency_cycles: 0,
        }
    }

    fn same(a: &[DesignPoint], b: &[DesignPoint]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.onchip_energy_pj.to_bits() == y.onchip_energy_pj.to_bits()
                    && x.area_mm2.to_bits() == y.area_mm2.to_bits()
            })
    }

    #[test]
    fn empty_and_singleton() {
        assert!(front(&[]).is_empty());
        let one = [pt(1.0, 1.0)];
        assert_eq!(front(&one).len(), 1);
    }

    #[test]
    fn staircase_survives_interior_removed() {
        let pts = [
            pt(1.0, 5.0),
            pt(2.0, 4.0), // dominated? no: higher e, lower a
            pt(3.0, 4.5), // dominated by (2.0, 4.0)
            pt(4.0, 1.0),
        ];
        let f = front(&pts);
        assert!(same(&f, &front_naive(&pts)));
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn duplicates_all_survive_together() {
        let pts = [pt(1.0, 2.0), pt(1.0, 2.0), pt(1.0, 3.0)];
        let f = front(&pts);
        // equal (e,a) pairs don't dominate each other; (1,3) is dominated
        assert_eq!(f.len(), 2);
        assert!(same(&f, &front_naive(&pts)));
    }

    #[test]
    fn equal_energy_larger_area_dominated() {
        let pts = [pt(1.0, 2.0), pt(1.0, 2.5)];
        let f = front(&pts);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].area_mm2, 2.0);
    }

    #[test]
    fn nan_points_survive_without_panicking() {
        // Regression: both fronts used `partial_cmp().unwrap()` and
        // panicked the moment a synthetic point carried a NaN
        // coordinate.  Under weak dominance a NaN coordinate is never
        // dominated and never dominates, so such points simply ride
        // along in both implementations.
        let pts = [
            pt(f64::NAN, 1.0),
            pt(2.0, f64::NAN),
            pt(1.0, 2.5), // dominated by (1.0, 2.0)
            pt(1.0, 2.0),
        ];
        let fast = front(&pts);
        let naive = front_naive(&pts);
        assert!(same(&fast, &naive), "fast {fast:?}\nnaive {naive:?}");
        assert_eq!(fast.len(), 3);
        // positive NaN energy sorts last under total_cmp
        assert_eq!(fast[0].onchip_energy_pj, 1.0);
        assert_eq!(fast[0].area_mm2, 2.0);
        assert_eq!(fast[1].onchip_energy_pj, 2.0);
        assert!(fast[1].area_mm2.is_nan());
        assert!(fast[2].onchip_energy_pj.is_nan());
    }

    #[test]
    fn best_energy_with_nan_returns_finite_min() {
        // Regression: `Explorer::best_energy` panicked on NaN via
        // `partial_cmp().unwrap()`; under total_cmp a positive NaN
        // sorts after every finite energy and the finite minimum wins.
        let pts = [pt(f64::NAN, 1.0), pt(1.0, 2.0), pt(3.0, 0.5)];
        let best = crate::dse::Explorer::best_energy(&pts).unwrap();
        assert_eq!(best.onchip_energy_pj, 1.0);
    }

    #[test]
    fn prop_fast_front_matches_naive() {
        check(Config::default().cases(60), |rng| {
            let n = rng.range(1, 120) as usize;
            let pts: Vec<DesignPoint> = (0..n)
                .map(|_| {
                    // coarse grid to force plenty of ties and duplicates
                    let e = rng.range(0, 12) as f64;
                    let a = rng.range(0, 12) as f64 / 2.0;
                    pt(e, a)
                })
                .collect();
            let fast = front(&pts);
            let naive = front_naive(&pts);
            assert!(
                same(&fast, &naive),
                "fast {fast:?}\nnaive {naive:?}"
            );
        });
    }
}
