//! Streaming 2D Pareto maintenance: an incremental skyline over
//! (on-chip energy, area) with O(log n) insert via binary search.
//!
//! [`pareto::front`](crate::dse::pareto::front) is a post-hoc filter —
//! it needs the whole sweep materialized before it can answer anything.
//! [`Skyline`] keeps the *incumbent* front live while the sweep is
//! still running, which is what feeds the dominance-aware
//! branch-and-bound in [`crate::dse::sweep::run_front`]: a geometry
//! subtree whose admissible [`ParetoBound`] is already strictly
//! dominated by some member is skipped before any of its points are
//! priced.
//!
//! Invariants (checked by the unit tests here and property-tested
//! against `pareto::front` in `tests/dse_parallel.rs`):
//!
//! * `groups` is a staircase: energies strictly increasing, areas
//!   strictly decreasing.  Each group holds every surviving point at
//!   exactly its (energy, area) — equal duplicates do not dominate one
//!   another, so all of them ride along, in insertion order.
//! * A point with a NaN coordinate is (by IEEE comparison semantics)
//!   never dominated and never dominates; it is parked off-staircase
//!   and always survives, exactly as `pareto::front_naive` keeps it.
//! * [`into_front`](Skyline::into_front) sorts members by
//!   (energy under `total_cmp`, enumeration sequence) — the same order
//!   `pareto::front` emits — so the final front is **independent of
//!   insertion order** and bit-identical to the post-hoc filter.

use std::cmp::Ordering;

use crate::analysis::bounds::ParetoBound;

use super::DesignPoint;

/// One staircase step: every surviving point at exactly this
/// (energy, area), in insertion order.
#[derive(Debug, Clone)]
struct Group {
    energy: f64,
    area: f64,
    /// `(enumeration sequence, point)`; the sequence recovers the
    /// sweep's canonical tie order in [`Skyline::into_front`].
    members: Vec<(u64, DesignPoint)>,
}

/// Incremental 2D skyline under weak (energy, area) dominance.
#[derive(Debug, Clone, Default)]
pub struct Skyline {
    /// The staircase (finite coordinates only): energy strictly
    /// increasing, area strictly decreasing.
    groups: Vec<Group>,
    /// Points with a NaN coordinate — neither dominated nor
    /// dominating, kept unconditionally.
    odd: Vec<(u64, DesignPoint)>,
}

impl Skyline {
    pub fn new() -> Skyline {
        Skyline::default()
    }

    /// Surviving points so far (duplicates counted).
    pub fn len(&self) -> usize {
        self.groups.iter().map(|g| g.members.len()).sum::<usize>()
            + self.odd.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty() && self.odd.is_empty()
    }

    /// Offer a point; returns whether it survives (i.e. is not
    /// dominated by a current member).  `seq` is the point's position
    /// in the sweep's canonical enumeration — it only matters for the
    /// tie order of [`into_front`](Self::into_front), which is what
    /// makes the final front insertion-order independent.
    pub fn insert(&mut self, seq: u64, point: DesignPoint) -> bool {
        let e = point.onchip_energy_pj;
        let a = point.area_mm2;
        if e.is_nan() || a.is_nan() {
            self.odd.push((seq, point));
            return true;
        }
        // first step with energy >= e
        let idx = self.groups.partition_point(|g| g.energy < e);
        // dominated by the strictly-cheaper predecessor step?
        if idx > 0 && self.groups[idx - 1].area <= a {
            return false;
        }
        if idx < self.groups.len() && self.groups[idx].energy == e {
            let g = &mut self.groups[idx];
            if g.area < a {
                // equal energy, strictly smaller incumbent area
                return false;
            }
            if g.area == a {
                // an exact duplicate is not dominated: both survive
                g.members.push((seq, point));
                return true;
            }
            // g.area > a: the new point strictly dominates this step
            // (and possibly later ones) — fall through to eviction
        }
        // evict every step the point dominates: they sit at
        // energy >= e with area >= a (the equal-(e, a) case was
        // handled above), and by the staircase invariant they form a
        // contiguous run starting at idx
        let mut end = idx;
        while end < self.groups.len() && self.groups[end].area >= a {
            end += 1;
        }
        self.groups.splice(
            idx..end,
            std::iter::once(Group {
                energy: e,
                area: a,
                members: vec![(seq, point)],
            }),
        );
        true
    }

    /// Would every point above `bound` be strictly dominated by a
    /// current member?  This is the branch-and-bound predicate: `true`
    /// means the whole subtree can be skipped without changing the
    /// final front.  Only *strict* dominance prunes — a member exactly
    /// at the bound must not reject a potential equal duplicate.
    pub fn prunes(&self, bound: &ParetoBound) -> bool {
        if bound.energy_lb_pj.is_nan() || bound.area_lb_mm2.is_nan() {
            return false;
        }
        // the best candidate dominator is the most expensive step with
        // energy <= bound energy (it has the smallest area among them)
        let idx = self
            .groups
            .partition_point(|g| g.energy <= bound.energy_lb_pj);
        if idx == 0 {
            return false;
        }
        let g = &self.groups[idx - 1];
        bound.dominated_by(g.energy, g.area)
    }

    /// Consume the skyline into the final front: members sorted by
    /// (energy under `total_cmp`, enumeration sequence) — exactly the
    /// output contract of [`pareto::front`](crate::dse::pareto::front),
    /// so the result does not depend on the order points were offered.
    pub fn into_front(self) -> Vec<DesignPoint> {
        let mut members: Vec<(u64, DesignPoint)> = self.odd;
        for g in self.groups {
            members.extend(g.members);
        }
        members.sort_by(|(sa, pa), (sb, pb)| {
            pa.onchip_energy_pj
                .total_cmp(&pb.onchip_energy_pj)
                .then(sa.cmp(sb))
        });
        members.into_iter().map(|(_, p)| p).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::capstore::arch::Organization;
    use crate::dse::pareto;

    fn pt(e: f64, a: f64) -> DesignPoint {
        DesignPoint {
            organization: Organization::Sep { gated: true },
            banks: 16,
            sectors: 64,
            dma: crate::timeline::DmaPolicy::default(),
            onchip_energy_pj: e,
            area_mm2: a,
            capacity_bytes: 0,
            latency_cycles: 0,
        }
    }

    fn front_of(pts: &[DesignPoint]) -> Vec<DesignPoint> {
        let mut sky = Skyline::new();
        for (i, p) in pts.iter().enumerate() {
            sky.insert(i as u64, p.clone());
        }
        sky.into_front()
    }

    fn same(a: &[DesignPoint], b: &[DesignPoint]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.bit_eq(y))
    }

    #[test]
    fn matches_post_hoc_front_on_handwritten_batches() {
        let batches: &[&[DesignPoint]] = &[
            &[],
            &[pt(1.0, 1.0)],
            &[pt(1.0, 5.0), pt(2.0, 4.0), pt(3.0, 4.5), pt(4.0, 1.0)],
            &[pt(1.0, 2.0), pt(1.0, 2.0), pt(1.0, 3.0)],
            &[pt(2.0, 2.0), pt(1.0, 3.0), pt(3.0, 1.0), pt(2.0, 2.0)],
            // eviction chain: a late cheap point wipes the staircase
            &[pt(5.0, 5.0), pt(4.0, 6.0), pt(3.0, 7.0), pt(1.0, 1.0)],
        ];
        for pts in batches {
            assert!(
                same(&front_of(pts), &pareto::front(pts)),
                "skyline diverged on {pts:?}"
            );
        }
    }

    #[test]
    fn duplicates_survive_in_enumeration_order() {
        // insert the duplicate pair in reverse enumeration order
        let a = pt(1.0, 2.0);
        let b = pt(1.0, 2.0);
        let mut sky = Skyline::new();
        assert!(sky.insert(7, b.clone()));
        assert!(sky.insert(3, a.clone()));
        assert_eq!(sky.len(), 2);
        let f = sky.into_front();
        // seq order, not insertion order
        assert_eq!(f.len(), 2);
        assert!(f[0].bit_eq(&a) && f[1].bit_eq(&b));
    }

    #[test]
    fn staircase_stays_sorted_under_eviction() {
        let mut sky = Skyline::new();
        for (i, p) in [
            pt(3.0, 3.0),
            pt(5.0, 1.0),
            pt(1.0, 5.0),
            pt(2.0, 2.0), // evicts (3,3)
            pt(0.5, 0.5), // evicts everything
        ]
        .into_iter()
        .enumerate()
        {
            sky.insert(i as u64, p);
        }
        let f = sky.into_front();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].onchip_energy_pj, 0.5);
    }

    #[test]
    fn prunes_requires_strict_dominance() {
        let mut sky = Skyline::new();
        sky.insert(0, pt(1.0, 2.0));
        let b = |e, a| ParetoBound { energy_lb_pj: e, area_lb_mm2: a };
        // a subtree bounded exactly at the incumbent may still hold an
        // equal duplicate: never pruned
        assert!(!sky.prunes(&b(1.0, 2.0)));
        // strictly worse on one axis, no better on the other: pruned
        assert!(sky.prunes(&b(1.5, 2.0)));
        assert!(sky.prunes(&b(1.0, 2.5)));
        assert!(sky.prunes(&b(9.0, 9.0)));
        // could still beat the incumbent somewhere: kept
        assert!(!sky.prunes(&b(0.5, 9.0)));
        assert!(!sky.prunes(&b(9.0, 1.0)));
        // NaN bounds never prune
        assert!(!sky.prunes(&b(f64::NAN, 9.0)));
    }

    #[test]
    fn nan_points_ride_along_unconditionally() {
        let pts =
            [pt(1.0, 1.0), pt(f64::NAN, 0.5), pt(2.0, 2.0), pt(0.5, f64::NAN)];
        let f = front_of(&pts);
        // (2,2) is dominated; the NaN points and (1,1) survive
        assert!(same(&f, &pareto::front(&pts)));
        assert_eq!(f.len(), 3);
        // and a NaN member never causes pruning
        let mut sky = Skyline::new();
        sky.insert(0, pt(f64::NAN, 0.0));
        assert!(!sky
            .prunes(&ParetoBound { energy_lb_pj: 9.0, area_lb_mm2: 9.0 }));
    }
}
