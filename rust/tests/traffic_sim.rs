//! Integration invariants of the traffic-driven serving simulator:
//! request conservation, bit-for-bit energy additivity, determinism,
//! break-even sleep monotonicity, and the serving-aware DSE regime
//! shift (the energy-optimal design point moves with the load).

use capstore::capsnet::CapsNetConfig;
use capstore::dse::Explorer;
use capstore::scenario::{Evaluator, Scenario};
use capstore::traffic::{
    rank_for_traffic, simulate, ArrivalPattern, ServiceModel,
    TrafficProfile,
};
use capstore::coordinator::BatchPolicy;
use std::time::Duration;

fn policy(max_batch: usize) -> BatchPolicy {
    BatchPolicy { max_batch, max_wait: Duration::from_millis(2) }
}

fn service_model(max_batch: usize) -> ServiceModel {
    ServiceModel::new(&Evaluator::new(), &Scenario::default(), max_batch)
        .unwrap()
}

/// Offered load that saturates the simulated accelerator `frac`-fold,
/// sized so roughly `arrivals` requests land in `duration_secs`.
fn profile_at(
    svc: &ServiceModel,
    frac: f64,
    arrivals: u64,
    seed: u64,
) -> TrafficProfile {
    let capacity =
        svc.clock_hz / svc.per_batch[0].latency_cycles as f64;
    let rate = frac * capacity;
    TrafficProfile {
        pattern: ArrivalPattern::Poisson,
        rate_per_sec: rate,
        seed,
        duration_secs: arrivals as f64 / rate,
        slo_ms: 1.0e6, // irrelevant unless a test says otherwise
    }
}

#[test]
fn requests_are_conserved_for_every_pattern_and_load() {
    let svc = service_model(8);
    for pattern in ArrivalPattern::all() {
        for frac in [0.2, 3.0] {
            let p = TrafficProfile {
                pattern,
                ..profile_at(&svc, frac, 300, 11)
            };
            let r = simulate(&svc, &p, &policy(8)).unwrap();
            assert!(r.arrivals > 0, "{pattern:?} x{frac}: no arrivals");
            assert_eq!(
                r.arrivals,
                r.served + r.queued,
                "{pattern:?} x{frac}: conservation"
            );
            assert_eq!(
                r.served,
                r.dispatches.iter().map(|d| d.size as u64).sum::<u64>(),
                "{pattern:?} x{frac}: served != dispatch sum"
            );
            // saturation must leave a backlog; light load must not
            if frac > 1.0 {
                assert!(r.queued > 0, "{pattern:?}: no backlog at x{frac}");
            }
        }
    }
}

#[test]
fn energy_is_additive_in_batch_energy_terms_bit_for_bit() {
    let ev = Evaluator::new();
    let sc = Scenario::default();
    let svc = ServiceModel::new(&ev, &sc, 8).unwrap();
    let p = profile_at(&svc, 1.2, 400, 7);
    let r = simulate(&svc, &p, &policy(8)).unwrap();
    assert!(r.batches > 1);

    // (1) the report total is the dispatch-order sum of batch_pj terms
    let mut sum = 0.0;
    for d in &r.dispatches {
        sum += d.batch_pj;
    }
    assert_eq!(sum.to_bits(), r.batch_pj.to_bits(), "additivity");

    // (2) each term is exactly the facade's BatchEnergy for that size
    let mut by_size: std::collections::HashMap<usize, f64> =
        std::collections::HashMap::new();
    for d in &r.dispatches {
        let pj = *by_size.entry(d.size).or_insert_with(|| {
            ev.evaluate_analytical(&Scenario {
                batch: d.size as u64,
                ..sc.clone()
            })
            .unwrap()
            .batch
            .total_pj()
        });
        assert_eq!(
            d.batch_pj.to_bits(),
            pj.to_bits(),
            "batch of {} diverged from BatchEnergy",
            d.size
        );
    }

    // (3) the decomposition closes: total = batches - warm + idle
    let total = r.batch_pj - r.warm_saving_pj + r.idle_pj;
    assert_eq!(total.to_bits(), r.total_pj().to_bits());
    assert!(r.idle_pj >= 0.0 && r.warm_saving_pj >= 0.0);
}

#[test]
fn same_seed_same_report_different_seed_different_arrivals() {
    let svc = service_model(8);
    for pattern in ArrivalPattern::all() {
        let p = TrafficProfile {
            pattern,
            ..profile_at(&svc, 0.6, 250, 21)
        };
        let a = simulate(&svc, &p, &policy(8)).unwrap();
        let b = simulate(&svc, &p, &policy(8)).unwrap();
        assert_eq!(
            a.to_json(svc.clock_hz).render(),
            b.to_json(svc.clock_hz).render(),
            "{pattern:?}: same seed diverged"
        );
        let c = simulate(
            &svc,
            &TrafficProfile { seed: 22, ..p.clone() },
            &policy(8),
        )
        .unwrap();
        assert_ne!(
            a.to_json(svc.clock_hz).render(),
            c.to_json(svc.clock_hz).render(),
            "{pattern:?}: seed is ignored"
        );
    }
}

#[test]
fn higher_rate_means_fewer_cold_starts() {
    // The break-even policy sleeps only across gaps longer than the
    // wakeup pay-back.  Raising the offered load shrinks the gaps, so
    // the cold-start count can only fall: at trickle load nearly every
    // batch wakes a cold memory, at saturation batches run back to
    // back and stay warm.
    let svc = service_model(8);
    assert!(svc.break_even_cycles.is_some(), "PG-SEP must gate");
    let cold = |frac: f64| {
        let p = profile_at(&svc, frac, 300, 13);
        let r = simulate(&svc, &p, &policy(8)).unwrap();
        assert_eq!(r.cold_starts + r.warm_starts, r.batches);
        r.cold_starts
    };
    let trickle = cold(0.05);
    let mid = cold(0.8);
    let saturated = cold(3.0);
    assert!(
        trickle >= mid && mid >= saturated,
        "cold starts not monotone: {trickle} / {mid} / {saturated}"
    );
    assert!(
        trickle > saturated,
        "no regime difference: {trickle} vs {saturated}"
    );
    // trickle load: essentially every batch is a cold start
    assert!(trickle > 100, "trickle produced only {trickle} cold starts");
    // saturation: back-to-back batches stay warm
    assert!(saturated < 10, "saturated still cold {saturated} times");
}

#[test]
fn slo_violations_appear_under_overload() {
    let svc = service_model(8);
    let service_ms =
        svc.per_batch[0].latency_cycles as f64 / svc.clock_hz * 1.0e3;
    // generous SLO at light load (50 services + the 2ms batcher wait):
    // no violations
    let mut light = profile_at(&svc, 0.1, 150, 17);
    light.slo_ms = 50.0 * service_ms + 5.0;
    let r_light = simulate(&svc, &light, &policy(8)).unwrap();
    assert_eq!(r_light.slo_violations, 0, "light load misses its SLO");
    // overload with the tightest possible SLO (one service time): the
    // queueing tail blows past it
    let mut heavy = profile_at(&svc, 4.0, 300, 17);
    heavy.slo_ms = service_ms;
    let r_heavy = simulate(&svc, &heavy, &policy(8)).unwrap();
    assert!(
        r_heavy.slo_violation_fraction() > 0.5,
        "overload at {}x: only {} violations",
        4.0,
        r_heavy.slo_violations
    );
    let s = r_heavy.latency_ms.as_ref().unwrap();
    assert!(s.p99 >= s.p95 && s.p95 >= s.median);
}

#[test]
fn serving_aware_dse_winner_shifts_with_the_load() {
    // The acceptance demo: same network, same tech node, two traffic
    // profiles — the energy-optimal design point differs.  At trickle
    // load the idle leakage of the sleeping memory dominates, favoring
    // the smallest-leakage gated design; at saturation the accelerator
    // never idles and the busy-energy winner of the classic DSE
    // reasserts itself.
    let ex = Explorer::new(CapsNetConfig::mnist());
    let front = Explorer::pareto(&ex.sweep().unwrap());
    // the regime shift needs at least two gated areas on the front
    let gated_areas: std::collections::HashSet<u64> = front
        .iter()
        .filter(|p| p.organization.gated())
        .map(|p| p.area_mm2.to_bits())
        .collect();
    assert!(gated_areas.len() >= 2, "front degenerate: {front:?}");

    let ev = Evaluator::new();
    let base = Scenario::default();
    let svc0 = ServiceModel::new(&ev, &base, 8).unwrap();
    let trickle = profile_at(&svc0, 0.005, 40, 7);
    let saturated = profile_at(&svc0, 3.0, 300, 7);
    let winners = rank_for_traffic(
        &ev,
        &base,
        &front,
        &[trickle, saturated],
        &policy(8),
    )
    .unwrap();
    assert_eq!(winners.len(), 2);
    let (low, high) = (&winners[0], &winners[1]);
    assert!(
        !low.point.bit_eq(&high.point),
        "same winner in both regimes: {:?}",
        low.point
    );
    // and the shift is the predicted one: the trickle winner leaks
    // less when parked than the saturated winner would
    assert!(low.point.organization.gated());
    assert!(
        low.point.area_mm2 < high.point.area_mm2,
        "trickle winner should be the smaller design: {} vs {}",
        low.point.area_mm2,
        high.point.area_mm2
    );
    // the saturated winner tracks the classic busy-energy optimum
    assert!(
        high.point.onchip_energy_pj <= low.point.onchip_energy_pj,
        "saturated winner is not the busier-optimal point"
    );
}
