//! Invariant suite for the cycle-resolved Timeline IR:
//!
//! 1. per-domain power-state segments are **non-overlapping and
//!    exhaustive** over `[0, total_cycles)`;
//! 2. op intervals (plus DMA stalls) **tile** the makespan, and with
//!    transfers hidden the totals equal `SweepContext::total_cycles`
//!    bit for bit;
//! 3. the timeline's cycle-weighted ON fraction is **bit-identical** to
//!    the gating plan's (the analytical model's static-energy input);
//! 4. batch / DMA-overlap knobs order energy and latency monotonically
//!    (the pinned smoke values of the refactor).

use capstore::analysis::breakdown::EnergyModel;
use capstore::capsnet::CapsNetConfig;
use capstore::capstore::arch::{CapStoreArch, Organization};
use capstore::capstore::pmu::GatingSchedule;
use capstore::scenario::{Evaluator, Scenario};
use capstore::testing::{check, Config};
use capstore::timeline::{
    DmaModel, DmaPolicy, GatingPolicy, PowerState, Timeline, TimelinePolicy,
};

fn assert_segments_tile(tl: &Timeline, tag: &str) {
    // domain count: one per (macro, sector)
    let expect: u64 = tl.macros.iter().map(|m| m.total_sectors).sum();
    assert_eq!(tl.domains.len() as u64, expect, "{tag}: domain count");

    for d in &tl.domains {
        let mut cursor = 0u64;
        for seg in &d.segments {
            assert_eq!(
                seg.interval.start, cursor,
                "{tag}: gap/overlap in domain ({}, {})",
                d.mac, d.sector
            );
            assert!(
                seg.interval.end > seg.interval.start,
                "{tag}: empty segment"
            );
            cursor = seg.interval.end;
        }
        assert_eq!(
            cursor, tl.total_cycles,
            "{tag}: domain ({}, {}) not exhaustive",
            d.mac, d.sector
        );
    }

    // ops + stalls tile the makespan
    let mut pieces: Vec<(u64, u64)> = tl
        .ops
        .iter()
        .map(|o| (o.interval.start, o.interval.end))
        .chain(tl.stalls.iter().map(|s| (s.interval.start, s.interval.end)))
        .collect();
    pieces.sort_unstable();
    let mut cursor = 0u64;
    for (s, e) in pieces {
        assert_eq!(s, cursor, "{tag}: op/stall tiling broken at {cursor}");
        cursor = e;
    }
    assert_eq!(cursor, tl.total_cycles, "{tag}: makespan not covered");
}

#[test]
fn prop_segments_nonoverlapping_exhaustive_across_the_space() {
    let model = EnergyModel::new(CapsNetConfig::mnist());
    let ctx = model.context();
    check(Config::default().cases(24), |rng| {
        let org = *rng.pick(&Organization::all());
        let banks = *rng.pick(&[4u64, 8, 16]);
        let sectors = *rng.pick(&[2u64, 8, 64, 128]);
        let arch = CapStoreArch::build(
            org,
            &model.req,
            &model.tech,
            banks,
            sectors,
        )
        .unwrap();
        let policy = TimelinePolicy {
            gating: GatingPolicy {
                lookahead_cycles: rng.range(0, 512),
            },
            dma: DmaPolicy {
                model: *rng.pick(&DmaModel::all()),
                bandwidth_bytes_per_cycle: rng.range(1, 64),
            },
            batch: rng.range(1, 4),
        };
        let tl = Timeline::build(&ctx, &arch, &model.req, &policy);
        let tag = format!("{} b{banks} s{sectors} {policy:?}", org.label());
        assert_segments_tile(&tl, &tag);

        // ungated timelines never leave the ON state
        if !org.gated() {
            for d in &tl.domains {
                assert_eq!(d.segments.len(), 1, "{tag}");
                assert_eq!(d.segments[0].state, PowerState::On, "{tag}");
            }
            assert_eq!(tl.transitions(), 0, "{tag}");
        }
    });
}

#[test]
fn hidden_transfer_totals_match_sweep_context_bit_for_bit() {
    for cfg in CapsNetConfig::all() {
        let model = EnergyModel::new(cfg.clone());
        let ctx = model.context();
        for org in Organization::all() {
            let arch =
                CapStoreArch::build_default(org, &model.req, &model.tech)
                    .unwrap();
            let tl = Timeline::build(
                &ctx,
                &arch,
                &model.req,
                &TimelinePolicy::default(),
            );
            assert_eq!(tl.total_cycles, ctx.total_cycles);
            assert_eq!(tl.inference_cycles, ctx.total_cycles);
            assert_eq!(tl.ops.len(), ctx.num_ops());
            // every op interval is exactly its context cycle count
            for (op, &cy) in tl.ops.iter().zip(&ctx.op_cycles) {
                assert_eq!(op.interval.cycles(), cy, "{}", cfg.name);
            }
        }
    }
}

#[test]
fn on_fraction_bit_identical_across_orgs_and_networks() {
    // the golden bridge between the IR and the analytical model: the
    // timeline's leakage weighting IS the plan's, bit for bit
    for cfg in CapsNetConfig::all() {
        let model = EnergyModel::new(cfg.clone());
        let ctx = model.context();
        for org in Organization::all() {
            let arch =
                CapStoreArch::build_default(org, &model.req, &model.tech)
                    .unwrap();
            let tl = Timeline::build(
                &ctx,
                &arch,
                &model.req,
                &TimelinePolicy::default(),
            );
            let plan =
                GatingSchedule::plan_for(&arch, &model.req, &ctx.op_kinds);
            for mac in 0..arch.macros.len() {
                assert_eq!(
                    tl.on_fraction(mac).to_bits(),
                    plan.on_fraction(mac, &ctx.op_cycles).to_bits(),
                    "{} {} macro {mac}",
                    cfg.name,
                    org.label()
                );
            }
        }
    }
}

#[test]
fn facade_design_points_match_sweep_points_on_the_dma_axis() {
    use capstore::dse::{sweep, SweepSpace};
    let ev = Evaluator::new();
    let model = EnergyModel::new(CapsNetConfig::mnist());
    let ctx = model.context();
    let space = SweepSpace {
        banks: vec![16],
        sectors: vec![64],
        organizations: vec![Organization::Sep { gated: true }],
        dma: DmaPolicy::all_models(),
    };
    let cache = sweep::CostCache::new();
    for spec in sweep::enumerate(&space) {
        let point =
            sweep::evaluate_point(&model, &ctx, &cache, &spec).unwrap();
        let sc = Scenario::builder()
            .organization(spec.organization)
            .banks(spec.banks)
            .sectors(spec.sectors)
            .dma_model(spec.dma.model)
            .build()
            .unwrap();
        let facade = ev.evaluate_analytical(&sc).unwrap().design_point();
        assert!(
            facade.bit_eq(&point),
            "facade vs sweep diverged:\n {facade:?}\n {point:?}"
        );
    }
}

#[test]
fn batch_and_overlap_smoke_values_are_monotone() {
    let ev = Evaluator::new();
    let base = Scenario::default(); // mnist/32nm/PG-SEP
    let e1 = ev.evaluate_analytical(&base).unwrap();

    // batch: energy per batch grows, energy per inference shrinks
    let mut prev_total = e1.batch_pj();
    let mut prev_per_inf = f64::INFINITY;
    for b in [2u64, 4, 8, 16] {
        let e = ev
            .evaluate_analytical(&Scenario { batch: b, ..base.clone() })
            .unwrap();
        let total = e.batch_pj();
        let per_inf = total / b as f64;
        assert!(total > prev_total, "batch {b}: {total} !> {prev_total}");
        assert!(
            per_inf < prev_per_inf,
            "batch {b}: per-inf {per_inf} !< {prev_per_inf}"
        );
        assert!(
            per_inf < e1.total_pj(),
            "batch {b}: pipelining must amortize the cold start"
        );
        prev_total = total;
        prev_per_inf = per_inf;
    }

    // overlap: hidden < double-buffered < serial on latency, and the
    // stall energy follows
    let lat = |m: DmaModel| {
        ev.evaluate_analytical(
            &Scenario::builder().dma_model(m).build().unwrap(),
        )
        .unwrap()
        .batch
        .latency_cycles
    };
    let (li, ld, ls) = (
        lat(DmaModel::Instant),
        lat(DmaModel::DoubleBuffered),
        lat(DmaModel::Serial),
    );
    assert!(li < ld && ld < ls, "latency order broken: {li} {ld} {ls}");
    // double buffering must actually hide a meaningful share of the
    // serial stall (pinned smoke ratio)
    let hidden = (ls - ld) as f64 / (ls - li) as f64;
    assert!(hidden > 0.05, "double buffering hides only {hidden:.3}");

    // bandwidth monotonicity: more bytes/cycle, less stall
    let lat_bw = |bw: u64| {
        ev.evaluate_analytical(
            &Scenario::builder()
                .dma_model(DmaModel::Serial)
                .dma_bandwidth(bw)
                .build()
                .unwrap(),
        )
        .unwrap()
        .batch
        .latency_cycles
    };
    assert!(lat_bw(8) > lat_bw(16));
    assert!(lat_bw(16) > lat_bw(64));
}
