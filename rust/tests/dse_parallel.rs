//! Determinism and equivalence contracts of the parallel incremental DSE
//! engine:
//!
//! 1. the parallel sweep is **bit-identical** (same order, same f64 bits)
//!    to the serial sweep AND to the pre-refactor baseline path
//!    (per-point context rebuild, uncached CACTI);
//! 2. the O(n log n) sort-and-scan Pareto front equals the naive O(n²)
//!    all-pairs front on arbitrary random point sets;
//! 3. the streaming [`Skyline`] is **insertion-order independent** —
//!    any permutation of the offers produces the same front as the
//!    post-hoc filter — and the dominance-aware branch-and-bound prunes
//!    without changing a single front bit, at any thread count.

use capstore::capsnet::CapsNetConfig;
use capstore::capstore::arch::Organization;
use capstore::dse::{
    pareto, DesignPoint, Explorer, MultiSweep, SweepSpace, Skyline,
};
use capstore::memsim::cacti::Technology;
use capstore::testing::{check, Config};
use capstore::timeline::DmaPolicy;

fn assert_bit_identical(a: &[DesignPoint], b: &[DesignPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.bit_eq(y),
            "{what}: point {i} diverged\n  a = {x:?}\n  b = {y:?}"
        );
    }
}

#[test]
fn parallel_sweep_bit_identical_to_serial_and_baseline() {
    for cfg in [CapsNetConfig::mnist(), CapsNetConfig::small()] {
        let mut ex = Explorer::new(cfg);
        ex.space = SweepSpace {
            banks: vec![2, 8, 16, 32],
            sectors: vec![4, 16, 64, 128],
            organizations: Organization::all().to_vec(),
            // cross the DMA axis too: identity must hold for the stall
            // pricing path, not just the hidden-transfer default
            dma: DmaPolicy::all_models(),
        };
        let baseline = ex.sweep_baseline().unwrap();
        let serial = ex.sweep_serial().unwrap();
        for threads in [2, 3, 4, 8] {
            let parallel = ex.sweep_with_threads(threads).unwrap();
            assert_bit_identical(
                &serial,
                &parallel,
                &format!("serial vs {threads} threads"),
            );
        }
        assert_bit_identical(&baseline, &serial, "baseline vs engine");
    }
}

#[test]
fn large_space_sweep_is_consistent() {
    let mut ex = Explorer::new(CapsNetConfig::mnist());
    ex.space = SweepSpace::large();
    let pts = ex.sweep().unwrap();
    assert_eq!(pts.len(), ex.space.num_points());
    assert!(pts.len() > 250, "large space should exceed 250 points");
    // every point evaluated to something physical
    for p in &pts {
        assert!(p.onchip_energy_pj.is_finite() && p.onchip_energy_pj > 0.0);
        assert!(p.area_mm2 > 0.0);
        assert!(p.capacity_bytes > 0);
    }
    // the paper's selection survives the finer axes
    let best = Explorer::best_energy(&pts).unwrap();
    assert_eq!(best.organization.label(), "PG-SEP");
}

#[test]
fn grand_sweep_covers_models_and_nodes() {
    // trim the space so the test stays quick while still crossing
    // model x tech boundaries
    let ms = MultiSweep {
        space: SweepSpace {
            banks: vec![8, 16],
            sectors: vec![16, 64],
            organizations: Organization::all().to_vec(),
            dma: vec![DmaPolicy::default()],
        },
        ..MultiSweep::default()
    };
    let all = ms.run().unwrap();
    assert_eq!(all.len(), ms.num_points());
    let nodes = Technology::nodes();
    for cfg in &ms.models {
        for (tech_name, _) in &nodes {
            let slice: Vec<_> = all
                .iter()
                .filter(|mp| mp.model == cfg.name && mp.tech == *tech_name)
                .collect();
            assert_eq!(slice.len(), 18, "{} @ {tech_name}", cfg.name);
        }
    }
    // energies differ across technology nodes for the same design point
    let pick = |tech: &str| {
        all.iter()
            .find(|mp| {
                mp.model == "mnist"
                    && mp.tech == tech
                    && mp.point.banks == 16
                    && mp.point.sectors == 64
                    && mp.point.organization.label() == "PG-SEP"
            })
            .unwrap()
            .point
            .onchip_energy_pj
    };
    assert!(pick("65nm") > pick("22nm"));
}

#[test]
fn prop_fast_pareto_matches_naive_on_random_sets() {
    fn pt(e: f64, a: f64) -> DesignPoint {
        DesignPoint {
            organization: Organization::Hy { gated: true },
            banks: 8,
            sectors: 32,
            dma: DmaPolicy::default(),
            onchip_energy_pj: e,
            area_mm2: a,
            capacity_bytes: 1,
            latency_cycles: 1,
        }
    }
    check(Config::default().cases(80), |rng| {
        let n = rng.range(1, 200) as usize;
        // mix continuous values with a coarse grid so ties, duplicates
        // and exact-equality corner cases all appear
        let pts: Vec<DesignPoint> = (0..n)
            .map(|_| {
                if rng.range(0, 2) == 0 {
                    pt(rng.f64() * 10.0, rng.f64() * 10.0)
                } else {
                    pt(rng.range(0, 8) as f64, rng.range(0, 8) as f64)
                }
            })
            .collect();
        let fast = pareto::front(&pts);
        let naive = pareto::front_naive(&pts);
        assert_eq!(fast.len(), naive.len(), "front size mismatch");
        for (f, nv) in fast.iter().zip(&naive) {
            assert!(
                f.bit_eq(nv),
                "front order/content mismatch:\n fast {f:?}\n naive {nv:?}"
            );
        }
    });
}

#[test]
fn prop_skyline_is_insertion_order_invariant() {
    fn pt(e: f64, a: f64) -> DesignPoint {
        DesignPoint {
            organization: Organization::Smp { gated: false },
            banks: 4,
            sectors: 16,
            dma: DmaPolicy::default(),
            onchip_energy_pj: e,
            area_mm2: a,
            capacity_bytes: 1,
            latency_cycles: 1,
        }
    }
    check(Config::default().cases(60), |rng| {
        let n = rng.range(1, 150) as usize;
        // half the cases draw from a tiny coarse grid — the adversarial
        // regime where equal-energy and equal-(energy, area) collisions
        // are everywhere and tie order is all that distinguishes fronts
        let grid_only = rng.chance(0.5);
        let pts: Vec<DesignPoint> = (0..n)
            .map(|_| {
                if grid_only || rng.range(0, 2) == 0 {
                    pt(rng.range(0, 4) as f64, rng.range(0, 4) as f64)
                } else {
                    pt(rng.f64() * 10.0, rng.f64() * 10.0)
                }
            })
            .collect();
        let expect = pareto::front(&pts);
        // offer the same points in several random permutations: the
        // front must not depend on insertion order, because the pruned
        // sweep admits points round by round, not in enumeration order
        for _ in 0..3 {
            let mut order: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                order.swap(i, rng.below(i as u64 + 1) as usize);
            }
            let mut sky = Skyline::new();
            for &i in &order {
                sky.insert(i as u64, pts[i].clone());
            }
            let got = sky.into_front();
            assert_bit_identical(&got, &expect, "skyline vs pareto::front");
        }
    });
}

#[test]
fn front_streaming_and_pruning_match_post_hoc_pareto_across_threads() {
    let mut ex = Explorer::new(CapsNetConfig::mnist());
    ex.space = SweepSpace::large();
    let post_hoc = pareto::front(&ex.sweep().unwrap());
    let specs = ex.space.num_points() as u64;

    let mut stats_by_prune = [None, None];
    for threads in [1usize, 4, 0] {
        ex.threads = threads;
        for prune in [false, true] {
            let (front, stats) = ex.sweep_front(prune).unwrap();
            assert_bit_identical(
                &front,
                &post_hoc,
                &format!("streamed front (threads={threads}, prune={prune})"),
            );
            assert_eq!(stats.specs, specs);
            assert_eq!(stats.front_len, front.len() as u64);
            assert_eq!(
                stats.pruned_points + stats.priced_points,
                stats.specs,
                "every spec is either pruned or priced"
            );
            if !prune {
                assert_eq!(stats.pruned_geometries, 0);
                assert_eq!(stats.priced_points, stats.specs);
            }
            // the counters themselves are part of the determinism
            // contract: identical at 1, 4, and all-cores threads
            let slot = &mut stats_by_prune[prune as usize];
            match slot {
                None => *slot = Some(stats),
                Some(first) => assert_eq!(
                    *first, stats,
                    "stats diverged across thread counts (prune={prune})"
                ),
            }
        }
    }
    let off = stats_by_prune[0].unwrap();
    let on = stats_by_prune[1].unwrap();
    assert!(
        on.priced_points <= off.priced_points,
        "pruning must never price more points than the exhaustive pass"
    );
}

#[test]
fn pareto_scales_past_the_quadratic_regime() {
    // sanity: the skyline of a big sweep output is well-formed
    let mut ex = Explorer::new(CapsNetConfig::mnist());
    ex.space = SweepSpace::large();
    let pts = ex.sweep().unwrap();
    let front = Explorer::pareto(&pts);
    assert!(!front.is_empty() && front.len() < pts.len());
    for p in &front {
        assert!(!pts.iter().any(|q| q.dominates(p)));
    }
}
