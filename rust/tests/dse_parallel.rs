//! Determinism and equivalence contracts of the parallel incremental DSE
//! engine:
//!
//! 1. the parallel sweep is **bit-identical** (same order, same f64 bits)
//!    to the serial sweep AND to the pre-refactor baseline path
//!    (per-point context rebuild, uncached CACTI);
//! 2. the O(n log n) sort-and-scan Pareto front equals the naive O(n²)
//!    all-pairs front on arbitrary random point sets.

use capstore::capsnet::CapsNetConfig;
use capstore::capstore::arch::Organization;
use capstore::dse::{pareto, DesignPoint, Explorer, MultiSweep, SweepSpace};
use capstore::memsim::cacti::Technology;
use capstore::testing::{check, Config};
use capstore::timeline::DmaPolicy;

fn assert_bit_identical(a: &[DesignPoint], b: &[DesignPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.bit_eq(y),
            "{what}: point {i} diverged\n  a = {x:?}\n  b = {y:?}"
        );
    }
}

#[test]
fn parallel_sweep_bit_identical_to_serial_and_baseline() {
    for cfg in [CapsNetConfig::mnist(), CapsNetConfig::small()] {
        let mut ex = Explorer::new(cfg);
        ex.space = SweepSpace {
            banks: vec![2, 8, 16, 32],
            sectors: vec![4, 16, 64, 128],
            organizations: Organization::all().to_vec(),
            // cross the DMA axis too: identity must hold for the stall
            // pricing path, not just the hidden-transfer default
            dma: DmaPolicy::all_models(),
        };
        let baseline = ex.sweep_baseline().unwrap();
        let serial = ex.sweep_serial().unwrap();
        for threads in [2, 3, 4, 8] {
            let parallel = ex.sweep_with_threads(threads).unwrap();
            assert_bit_identical(
                &serial,
                &parallel,
                &format!("serial vs {threads} threads"),
            );
        }
        assert_bit_identical(&baseline, &serial, "baseline vs engine");
    }
}

#[test]
fn large_space_sweep_is_consistent() {
    let mut ex = Explorer::new(CapsNetConfig::mnist());
    ex.space = SweepSpace::large();
    let pts = ex.sweep().unwrap();
    assert_eq!(pts.len(), ex.space.num_points());
    assert!(pts.len() > 250, "large space should exceed 250 points");
    // every point evaluated to something physical
    for p in &pts {
        assert!(p.onchip_energy_pj.is_finite() && p.onchip_energy_pj > 0.0);
        assert!(p.area_mm2 > 0.0);
        assert!(p.capacity_bytes > 0);
    }
    // the paper's selection survives the finer axes
    let best = Explorer::best_energy(&pts).unwrap();
    assert_eq!(best.organization.label(), "PG-SEP");
}

#[test]
fn grand_sweep_covers_models_and_nodes() {
    // trim the space so the test stays quick while still crossing
    // model x tech boundaries
    let ms = MultiSweep {
        space: SweepSpace {
            banks: vec![8, 16],
            sectors: vec![16, 64],
            organizations: Organization::all().to_vec(),
            dma: vec![DmaPolicy::default()],
        },
        ..MultiSweep::default()
    };
    let all = ms.run().unwrap();
    assert_eq!(all.len(), ms.num_points());
    let nodes = Technology::nodes();
    for cfg in &ms.models {
        for (tech_name, _) in &nodes {
            let slice: Vec<_> = all
                .iter()
                .filter(|mp| mp.model == cfg.name && mp.tech == *tech_name)
                .collect();
            assert_eq!(slice.len(), 18, "{} @ {tech_name}", cfg.name);
        }
    }
    // energies differ across technology nodes for the same design point
    let pick = |tech: &str| {
        all.iter()
            .find(|mp| {
                mp.model == "mnist"
                    && mp.tech == tech
                    && mp.point.banks == 16
                    && mp.point.sectors == 64
                    && mp.point.organization.label() == "PG-SEP"
            })
            .unwrap()
            .point
            .onchip_energy_pj
    };
    assert!(pick("65nm") > pick("22nm"));
}

#[test]
fn prop_fast_pareto_matches_naive_on_random_sets() {
    fn pt(e: f64, a: f64) -> DesignPoint {
        DesignPoint {
            organization: Organization::Hy { gated: true },
            banks: 8,
            sectors: 32,
            dma: DmaPolicy::default(),
            onchip_energy_pj: e,
            area_mm2: a,
            capacity_bytes: 1,
            latency_cycles: 1,
        }
    }
    check(Config::default().cases(80), |rng| {
        let n = rng.range(1, 200) as usize;
        // mix continuous values with a coarse grid so ties, duplicates
        // and exact-equality corner cases all appear
        let pts: Vec<DesignPoint> = (0..n)
            .map(|_| {
                if rng.range(0, 2) == 0 {
                    pt(rng.f64() * 10.0, rng.f64() * 10.0)
                } else {
                    pt(rng.range(0, 8) as f64, rng.range(0, 8) as f64)
                }
            })
            .collect();
        let fast = pareto::front(&pts);
        let naive = pareto::front_naive(&pts);
        assert_eq!(fast.len(), naive.len(), "front size mismatch");
        for (f, nv) in fast.iter().zip(&naive) {
            assert!(
                f.bit_eq(nv),
                "front order/content mismatch:\n fast {f:?}\n naive {nv:?}"
            );
        }
    });
}

#[test]
fn pareto_scales_past_the_quadratic_regime() {
    // sanity: the skyline of a big sweep output is well-formed
    let mut ex = Explorer::new(CapsNetConfig::mnist());
    ex.space = SweepSpace::large();
    let pts = ex.sweep().unwrap();
    let front = Explorer::pareto(&pts);
    assert!(!front.is_empty() && front.len() < pts.len());
    for p in &front {
        assert!(!pts.iter().any(|q| q.dominates(p)));
    }
}
