//! Cross-module integration tests: the analysis pipeline end to end,
//! the DSE against the energy model, config files driving real builds,
//! and (when artifacts exist) the PJRT runtime against the simulator's
//! view of the very same network.

use std::path::PathBuf;

use capstore::accel::systolic::{ArrayConfig, SystolicSim};
use capstore::analysis::breakdown::EnergyModel;
use capstore::analysis::offchip::OffChipTraffic;
use capstore::analysis::requirements::RequirementsAnalysis;
use capstore::capsnet::{CapsNetConfig, OpKind, Operation};
use capstore::capstore::arch::{CapStoreArch, MemoryRole, Organization};
use capstore::capstore::pmu::GatingSchedule;
use capstore::config::schema::RunConfig;
use capstore::config::toml::TomlDoc;
use capstore::dse::Explorer;
use capstore::memsim::cacti::Technology;
use capstore::report::paper::PaperReference;
use capstore::testing::{check, Config};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

// ---------------------------------------------------------------------
// analysis pipeline end-to-end
// ---------------------------------------------------------------------

#[test]
fn full_pipeline_reproduces_headline_claims() {
    let model = EnergyModel::new(CapsNetConfig::mnist());
    let a = model.all_onchip_baseline().unwrap();
    let smp = CapStoreArch::build_default(
        Organization::Smp { gated: false },
        &model.req,
        &model.tech,
    )
    .unwrap();
    let pg_sep = CapStoreArch::build_default(
        Organization::Sep { gated: true },
        &model.req,
        &model.tech,
    )
    .unwrap();
    let b = model.system_energy(&smp);
    let c = model.system_energy(&pg_sep);

    // paper's five headline claims, at shape level
    assert!(a.memory_share() > 0.90, "96% memory share");
    let hierarchy = 1.0 - b.total_pj() / a.total_pj();
    assert!((hierarchy - PaperReference::HIERARCHY_SAVING).abs() < 0.15);
    let onchip = 1.0 - c.onchip_pj / b.onchip_pj;
    assert!(onchip > 0.6, "86% on-chip saving claim, ours {onchip}");
    let vs_a = 1.0 - c.total_pj() / a.total_pj();
    assert!((vs_a - PaperReference::PG_SEP_TOTAL_VS_A).abs() < 0.10);
    let vs_b = 1.0 - c.total_pj() / b.total_pj();
    assert!((vs_b - PaperReference::PG_SEP_TOTAL_VS_B).abs() < 0.10);
}

#[test]
fn dse_selects_the_papers_architecture() {
    let ex = Explorer::new(CapsNetConfig::mnist());
    let pts = ex.sweep().unwrap();
    let best = Explorer::best_energy(&pts).unwrap();
    assert_eq!(best.organization.label(), "PG-SEP");
    // and the front contains at least one gated and one ungated point
    let front = Explorer::pareto(&pts);
    assert!(front.iter().any(|p| p.organization.gated()));
}

#[test]
fn gating_schedule_respects_capacity_for_every_arch() {
    let cfg = CapsNetConfig::mnist();
    let sim = SystolicSim::default();
    let req = RequirementsAnalysis::analyze(&cfg, &sim.array);
    for org in Organization::all() {
        let arch =
            CapStoreArch::build_default(org, &req, &Technology::default())
                .unwrap();
        let plan = GatingSchedule::plan(&arch, &req, &cfg);
        for (kind, on) in &plan.steps {
            for (i, m) in arch.macros.iter().enumerate() {
                assert!(
                    on[i] <= m.sram.sectors,
                    "{}: {kind:?} macro {i} over capacity",
                    org.label()
                );
                // ON sectors must cover the op's need for that macro
                if org.gated() && m.role != MemoryRole::Shared {
                    let need = match m.role {
                        MemoryRole::Weight => req.get(*kind).weight,
                        MemoryRole::Data => req.get(*kind).data,
                        MemoryRole::Accumulator => req.get(*kind).accum,
                        MemoryRole::Shared => 0,
                    }
                    .min(m.sram.size_bytes);
                    let covered = on[i] * (m.sram.size_bytes / m.sram.sectors);
                    assert!(
                        covered >= need,
                        "{}: {kind:?} {:?} covers {covered} < need {need}",
                        org.label(),
                        m.role
                    );
                }
            }
        }
    }
}

#[test]
fn offchip_traffic_consistent_with_requirements() {
    // ops whose inputs are 0 off-chip must be exactly the ops whose
    // data comes from on-chip residents
    let cfg = CapsNetConfig::mnist();
    let sim = SystolicSim::default();
    let traffic = OffChipTraffic::analyze(&cfg, &sim);
    for (t, op) in traffic.iter().zip(Operation::all_kinds(&cfg)) {
        assert_eq!(
            t.reads == 0 && t.writes == 0,
            op.on_chip_only,
            "{:?}",
            t.kind
        );
    }
}

// ---------------------------------------------------------------------
// property tests across module boundaries
// ---------------------------------------------------------------------

#[test]
fn prop_energy_model_monotone_in_utilization_time() {
    // a network with more routing iterations can never consume less
    // on-chip energy (more ops, more accesses, more leakage time)
    check(Config::default().cases(8), |rng| {
        let base_iters = rng.range(1, 4);
        let mut cfg1 = CapsNetConfig::mnist();
        cfg1.routing_iters = base_iters;
        let mut cfg2 = cfg1.clone();
        cfg2.routing_iters = base_iters + 1;

        let m1 = EnergyModel::new(cfg1);
        let m2 = EnergyModel::new(cfg2);
        let a1 = CapStoreArch::build_default(
            Organization::Sep { gated: true },
            &m1.req,
            &m1.tech,
        )
        .unwrap();
        let a2 = CapStoreArch::build_default(
            Organization::Sep { gated: true },
            &m2.req,
            &m2.tech,
        )
        .unwrap();
        let e1 = m1.evaluate_arch(&a1).onchip_pj;
        let e2 = m2.evaluate_arch(&a2).onchip_pj;
        assert!(e2 > e1, "iters {base_iters}: {e2} <= {e1}");
    });
}

#[test]
fn prop_any_valid_geometry_builds_and_evaluates() {
    let model = EnergyModel::new(CapsNetConfig::mnist());
    check(Config::default().cases(24), |rng| {
        let banks = *rng.pick(&[1u64, 2, 4, 8, 16, 32]);
        let sectors = *rng.pick(&[1u64, 2, 8, 32, 128]);
        let org = *rng.pick(&Organization::all());
        let arch = CapStoreArch::build(
            org,
            &model.req,
            &model.tech,
            banks,
            sectors,
        )
        .unwrap();
        let e = model.evaluate_arch(&arch);
        assert!(e.onchip_pj.is_finite() && e.onchip_pj > 0.0);
        assert!(e.area_mm2 > 0.0);
        // capacity covers the worst case in every organization
        assert!(arch.capacity() >= model.req.max_total());
    });
}

#[test]
fn prop_cycles_scale_with_network_width() {
    // wider conv1 -> more MACs -> more cycles, in any valid config
    check(Config::default().cases(10), |rng| {
        let w = 32 * rng.range(1, 8);
        let mut small = CapsNetConfig::mnist();
        small.conv1_channels = w;
        small.pc_channels = 256;
        let mut big = small.clone();
        big.conv1_channels = w * 2;
        let sim = SystolicSim::default();
        let (_, c_small) = sim.profile_schedule(&small);
        let (_, c_big) = sim.profile_schedule(&big);
        assert!(c_big > c_small);
    });
}

// ---------------------------------------------------------------------
// config-driven construction
// ---------------------------------------------------------------------

#[test]
fn config_file_drives_a_real_build() {
    let doc = TomlDoc::parse(
        "model = \"mnist\"\n[memory]\norganization = \"PG-HY\"\nbanks = 8\nsectors = 32\n",
    )
    .unwrap();
    let rc = RunConfig::from_toml(&doc).unwrap();
    let cfg = CapsNetConfig::by_name(&rc.model).unwrap();
    let model = EnergyModel::new(cfg);
    let arch = CapStoreArch::build(
        rc.organization,
        &model.req,
        &model.tech,
        rc.banks,
        rc.sectors,
    )
    .unwrap();
    assert_eq!(arch.organization.label(), "PG-HY");
    assert!(arch.macros.iter().all(|m| m.sram.banks == 8));
    assert!(arch
        .macros
        .iter()
        .all(|m| !arch.organization.gated() || m.sram.sectors == 32));
}

// ---------------------------------------------------------------------
// runtime vs simulator consistency (needs artifacts)
// ---------------------------------------------------------------------

#[test]
fn runtime_and_simulator_agree_on_geometry() {
    let Some(dir) = artifacts() else { return };
    use capstore::runtime::manifest::ArtifactManifest;
    let m = ArtifactManifest::load(&dir).unwrap();
    for (name, _) in &m.configs {
        let cfg = CapsNetConfig::by_name(name).expect("rust mirror exists");
        m.validate_against(name, &cfg).unwrap();
        // the simulator can analyze exactly what the runtime executes
        let sim = SystolicSim::default();
        let req = RequirementsAnalysis::analyze(&cfg, &sim.array);
        assert!(req.max_total() > 0);
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn served_inference_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    use capstore::runtime::engine::InferenceEngine;
    let eng = InferenceEngine::load(&dir, "small").unwrap();
    let img: Vec<f32> = (0..784).map(|i| ((i * 37) % 255) as f32 / 255.0).collect();
    let a = eng.infer(&[img.clone()]).unwrap();
    let b = eng.infer(&[img]).unwrap();
    assert_eq!(a[0].predicted, b[0].predicted);
    for (x, y) in a[0].class_capsules.iter().zip(&b[0].class_capsules) {
        assert_eq!(x, y, "PJRT execution must be bit-deterministic");
    }
}

#[test]
fn per_op_artifacts_cover_the_schedule() {
    let Some(dir) = artifacts() else { return };
    use capstore::runtime::manifest::ArtifactManifest;
    let m = ArtifactManifest::load(&dir).unwrap();
    let entry = m.config("small").unwrap();
    // the staged pipeline has artifacts for exactly the four fused stages
    // (conv1, primarycaps, classcaps_fc, routing); the simulator's five
    // Fig-4 operations map onto them with routing = SumSquash+UpdateSum
    for op in ["conv1", "primarycaps", "classcaps_fc", "routing"] {
        assert!(entry.ops.contains_key(op), "missing op artifact {op}");
        assert!(m.path(&entry.ops[op]).exists());
    }
    let kinds = [
        OpKind::Conv1,
        OpKind::PrimaryCaps,
        OpKind::ClassCapsFc,
        OpKind::SumSquash,
        OpKind::UpdateSum,
    ];
    assert_eq!(kinds.len(), 5);
}
