//! End-to-end invariants of the deterministic telemetry layer:
//!
//! 1. **canonical order** — exported events are totally ordered and
//!    spans never overlap within a track (the op track instead nests
//!    properly: tiles sit strictly inside their op span);
//! 2. **bit-for-bit reconciliation** — every power-state span matches
//!    its `Timeline` segment in extent and energy, and the in-order
//!    sum of span energies reproduces `Timeline::static_pj()` exactly;
//! 3. **byte determinism** — the same scenario/seed renders the same
//!    `trace.json` bytes, twice, for both the timeline and the traced
//!    serving run (plus a blessable golden, CI's trace-smoke anchor);
//! 4. **counter conservation** — a `CounterSnapshot` of a faulty run
//!    satisfies the traffic conservation law;
//! 5. **zero overhead** — tracing (on or off) builds zero extra
//!    `Timeline` IRs in the serving event loop.

use std::time::Duration;

use capstore::accel::systolic::ArrayConfig;
use capstore::analysis::breakdown::EnergyModel;
use capstore::coordinator::BatchPolicy;
use capstore::faults::{FaultPlan, ResiliencePolicy};
use capstore::scenario::{Evaluator, Scenario};
use capstore::telemetry::{
    perfetto, trace_timeline, trace_tiles, Arg, CounterRegistry,
    EventKind, TraceSink,
};
use capstore::timeline::Timeline;
use capstore::traffic::{
    simulate, simulate_traced, ArrivalPattern, ServiceModel,
    TrafficProfile,
};

fn policy(max_batch: usize) -> BatchPolicy {
    BatchPolicy { max_batch, max_wait: Duration::from_millis(2) }
}

fn profile(seed: u64) -> TrafficProfile {
    TrafficProfile {
        pattern: ArrivalPattern::Bursty,
        rate_per_sec: 4000.0,
        seed,
        duration_secs: 0.05,
        slo_ms: 5.0,
    }
}

fn faulty_plan() -> FaultPlan {
    FaultPlan {
        seed: 99,
        wake_fail_rate: 0.3,
        dma_degrade_rate: 0.3,
        dma_degrade_dwell_secs: 0.005,
        slowdown_rate: 0.3,
        slowdown_dwell_secs: 0.005,
        drop_rate: 0.05,
        duplicate_rate: 0.05,
        ..FaultPlan::none()
    }
}

fn resilience() -> ResiliencePolicy {
    ResiliencePolicy {
        queue_cap: Some(64),
        timeout_ms: Some(5.0),
        retry_budget: 1,
        ..ResiliencePolicy::none()
    }
}

/// A full timeline trace (ops + tiles + DMA + power) of the default
/// scenario, plus the timeline it was exported from.
fn timeline_trace() -> (TraceSink, capstore::scenario::Evaluation) {
    let sc = Scenario::default();
    let e = Evaluator::new().evaluate(&sc).unwrap();
    let mut sink = TraceSink::new();
    trace_timeline(&mut sink, e.timeline());
    let model = EnergyModel::new(sc.network.clone());
    let ctx = model.context();
    trace_tiles(
        &mut sink,
        e.timeline(),
        &ctx.schedule,
        &ArrayConfig::default(),
    );
    (sink, e)
}

#[test]
fn exported_events_are_ordered_and_tracks_never_overlap() {
    let (sink, _e) = timeline_trace();
    let sorted = sink.sorted_events();
    // total order: (track, ts, seq) strictly increases
    for w in sorted.windows(2) {
        let (a, b) = (w[0], w[1]);
        assert!(
            (a.track, a.ts, a.seq) < (b.track, b.ts, b.seq),
            "emission order is not total"
        );
    }
    // spans on every track either stay disjoint or nest properly
    // (tiles inside their op on the ops track); a stack catches both:
    // each span must start after — or fit entirely inside — the
    // innermost open span
    let mut by_track: std::collections::BTreeMap<
        usize,
        Vec<(u64, u64)>,
    > = std::collections::BTreeMap::new();
    for e in &sorted {
        if let EventKind::Span { dur } = e.kind {
            by_track
                .entry(e.track.0)
                .or_default()
                .push((e.ts, e.ts + dur));
        }
    }
    for (track, spans) in by_track {
        let mut stack: Vec<(u64, u64)> = Vec::new();
        for (s, t) in spans {
            while let Some(&(_, open_end)) = stack.last() {
                if open_end <= s {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&(open_start, open_end)) = stack.last() {
                assert!(
                    s >= open_start && t <= open_end,
                    "track {track}: span [{s},{t}) straddles \
                     [{open_start},{open_end})"
                );
            }
            stack.push((s, t));
        }
    }
}

#[test]
fn power_spans_reconcile_bit_for_bit_with_the_timeline() {
    let (sink, e) = timeline_trace();
    let tl = e.timeline();
    // power-track span events in recording order mirror the IR's
    // domain/segment nesting order exactly
    let power: Vec<&capstore::telemetry::Event> = sink
        .events()
        .iter()
        .filter(|ev| sink.track_labels(ev.track).0 == "power")
        .collect();
    let seg_total: usize =
        tl.domains.iter().map(|d| d.segments.len()).sum();
    assert_eq!(power.len(), seg_total, "a segment is missing its span");

    let mut i = 0;
    let mut span_sum = 0.0f64;
    let mut seg_sum = 0.0f64;
    for d in &tl.domains {
        for seg in &d.segments {
            let ev = power[i];
            i += 1;
            assert_eq!(ev.ts, seg.interval.start, "span start drifted");
            match ev.kind {
                EventKind::Span { dur } => {
                    assert_eq!(
                        dur,
                        seg.interval.cycles(),
                        "span extent drifted"
                    );
                }
                _ => panic!("power event must be a span"),
            }
            assert_eq!(
                sink.name(ev.name),
                seg.state.label(),
                "span power-state name drifted"
            );
            let pj = match ev.args.first() {
                Some((_, Arg::F64(v))) => *v,
                other => panic!("energy_pj arg missing: {other:?}"),
            };
            let want = tl.segment_static_pj(d, seg);
            assert_eq!(
                pj.to_bits(),
                want.to_bits(),
                "span energy attribution drifted"
            );
            span_sum += pj;
            seg_sum += want;
        }
    }
    // the in-order sum over spans IS the IR's static energy, exactly
    assert_eq!(span_sum.to_bits(), seg_sum.to_bits());
    assert_eq!(span_sum.to_bits(), tl.static_pj().to_bits());
}

#[test]
fn timeline_trace_renders_byte_identical_json() {
    let (a, _) = timeline_trace();
    let (b, _) = timeline_trace();
    let ra = perfetto::render(&a);
    let rb = perfetto::render(&b);
    assert!(!ra.is_empty());
    assert_eq!(ra, rb, "timeline trace is not byte-deterministic");

    // blessable golden — the in-process anchor of CI's trace-smoke
    // job (tests/golden/README.md explains the bootstrap)
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/trace_timeline.json");
    let bless = std::env::var_os("CAPSTORE_BLESS").is_some();
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &ra).unwrap();
        eprintln!("blessed {} — commit it to pin", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        ra,
        want,
        "trace drifted from {}; re-bless with CAPSTORE_BLESS=1 if \
         intentional",
        path.display()
    );
}

#[test]
fn traced_serving_run_renders_byte_identical_json() {
    let ev = Evaluator::new();
    let sc = Scenario::default();
    let faults = faulty_plan();
    let svc =
        ServiceModel::with_faults(&ev, &sc, 4, Some(&faults)).unwrap();
    let run = || {
        let mut sink = TraceSink::new();
        let report = simulate_traced(
            &svc,
            &profile(3),
            &policy(4),
            &faults,
            &resilience(),
            Some(&mut sink),
        )
        .unwrap();
        (perfetto::render(&sink), report)
    };
    let (ra, report) = run();
    let (rb, _) = run();
    assert_eq!(ra, rb, "traced serving run is not byte-deterministic");
    assert!(report.arrivals > 0);
    // a different seed must not render the same bytes (the trace
    // really is a function of the inputs, not a constant)
    let mut sink = TraceSink::new();
    simulate_traced(
        &svc,
        &profile(4),
        &policy(4),
        &faults,
        &resilience(),
        Some(&mut sink),
    )
    .unwrap();
    assert_ne!(ra, perfetto::render(&sink));
}

#[test]
fn counter_snapshot_satisfies_the_conservation_law() {
    let ev = Evaluator::new();
    let sc = Scenario::default();
    let faults = faulty_plan();
    let svc =
        ServiceModel::with_faults(&ev, &sc, 4, Some(&faults)).unwrap();
    let report = simulate_traced(
        &svc,
        &profile(3),
        &policy(4),
        &faults,
        &resilience(),
        None,
    )
    .unwrap();
    let s = CounterRegistry::from_traffic_report(&report).snapshot();
    // something actually went wrong in this run, so the law is not
    // trivially 0 == 0
    assert!(s.get("faults.wake_failures") > 0);
    assert_eq!(
        s.get("faults.wake_retries"),
        s.get("faults.wake_failures")
    );
    assert_eq!(
        s.get("traffic.arrivals")
            + s.get("traffic.duplicated")
            + s.get("traffic.retried"),
        s.get("traffic.served")
            + s.get("traffic.queued")
            + s.get("traffic.shed")
            + s.get("traffic.dropped")
            + s.get("traffic.timed_out"),
        "counter snapshot breaks the conservation law"
    );
    // and the snapshot agrees with the report it came from
    assert_eq!(s.get("traffic.arrivals"), report.arrivals);
    assert_eq!(s.get("traffic.served"), report.served);
    assert_eq!(s.get("traffic.shed"), report.resilience.shed);
}

#[test]
fn tracing_builds_zero_extra_timelines() {
    let ev = Evaluator::new();
    let sc = Scenario::default();
    let svc = ServiceModel::new(&ev, &sc, 4).unwrap();
    let p = profile(7);

    // tracing OFF: the serving event loop builds no IRs (the bench
    // contract), and the traced entry point with `None` is identical
    let before = Timeline::build_count();
    let plain = simulate(&svc, &p, &policy(4)).unwrap();
    assert_eq!(
        Timeline::build_count(),
        before,
        "untraced event loop built a Timeline"
    );

    // tracing ON: recording reads existing results only — still zero
    let before = Timeline::build_count();
    let mut sink = TraceSink::new();
    let traced = simulate_traced(
        &svc,
        &p,
        &policy(4),
        &FaultPlan::none(),
        &ResiliencePolicy::none(),
        Some(&mut sink),
    )
    .unwrap();
    assert_eq!(
        Timeline::build_count(),
        before,
        "tracing built an extra Timeline"
    );
    assert!(!sink.is_empty());
    // and the traced run's report is the plain run's report, exactly
    assert_eq!(
        plain.to_json(svc.clock_hz).render(),
        traced.to_json(svc.clock_hz).render(),
        "tracing perturbed the report"
    );
}
