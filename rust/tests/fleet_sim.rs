//! End-to-end invariants of the fleet layer:
//!
//! 1. **byte determinism** — the same seed renders the same
//!    `FleetReport` JSON bytes, twice, from independently built
//!    service models, for every dispatch policy;
//! 2. **conservation under saturation** — `arrivals == served +
//!    queued + shed` holds across arrival patterns even when the
//!    offered load far exceeds the fleet's capacity;
//! 3. **winner shift** — at a rate that saturates one instance but
//!    not the fleet, power-aware packing gates whole instances off
//!    and beats round-robin on energy per served inference, while JSQ
//!    and packing genuinely disagree;
//! 4. **fleet DSE acceptance** — `rank_fleet` strictly beats N copies
//!    of the single-design `rank_for_traffic` winner under
//!    round-robin, byte-identically across repeated seeded runs;
//! 5. **zero overhead** — the fleet event loop builds no `Timeline`
//!    IRs, traced or untraced, and tracing never perturbs the report.

use std::time::Duration;

use capstore::coordinator::BatchPolicy;
use capstore::dse::Explorer;
use capstore::fleet::{
    simulate_fleet, simulate_fleet_traced, DispatchPolicy, FleetSpec,
};
use capstore::scenario::{Evaluator, Scenario};
use capstore::telemetry::{perfetto, TraceSink};
use capstore::timeline::Timeline;
use capstore::traffic::{
    rank_fleet, rank_for_traffic, ArrivalPattern, ServiceModel,
    TrafficProfile,
};

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
}

fn profile(rate: f64, duration: f64) -> TrafficProfile {
    TrafficProfile {
        pattern: ArrivalPattern::Poisson,
        rate_per_sec: rate,
        seed: 7,
        duration_secs: duration,
        slo_ms: 50.0,
    }
}

fn homogeneous(n: usize) -> Vec<ServiceModel> {
    let svc = ServiceModel::new(
        &Evaluator::new(),
        &Scenario::default(),
        policy().max_batch,
    )
    .unwrap();
    vec![svc; n]
}

#[test]
fn same_seed_is_byte_identical_for_every_policy() {
    for dispatch in DispatchPolicy::all() {
        let run = || {
            // build everything from scratch: determinism must not
            // depend on reusing a warm ServiceModel
            let spec = FleetSpec {
                instances: 3,
                policy: dispatch,
                elastic: true,
                scale_up_depth: 4,
                min_active: 1,
            };
            let report = simulate_fleet(
                &homogeneous(3),
                &profile(2000.0, 0.05),
                &policy(),
                &spec,
            )
            .unwrap();
            assert!(report.conserves(), "{dispatch:?}");
            report.to_json().render()
        };
        assert_eq!(run(), run(), "{dispatch:?} is not deterministic");
    }
}

#[test]
fn conservation_holds_under_saturation() {
    // ~2.5x the whole fleet's capacity: queues must grow, yet every
    // arrival is accounted for at the horizon.
    for pattern in [
        ArrivalPattern::Poisson,
        ArrivalPattern::Bursty,
        ArrivalPattern::Diurnal,
    ] {
        for dispatch in DispatchPolicy::all() {
            let prof = TrafficProfile {
                pattern,
                ..profile(5000.0, 0.05)
            };
            let spec = FleetSpec {
                instances: 2,
                policy: dispatch,
                ..FleetSpec::default()
            };
            let report =
                simulate_fleet(&homogeneous(2), &prof, &policy(), &spec)
                    .unwrap();
            assert!(
                report.conserves(),
                "{pattern:?}/{dispatch:?}: {} != {} + {} + {}",
                report.arrivals,
                report.served,
                report.queued,
                report.shed,
            );
            assert!(report.arrivals > 0);
            assert!(
                report.queued > 0,
                "{pattern:?}/{dispatch:?}: saturation left no backlog"
            );
        }
    }
}

#[test]
fn packing_gates_instances_off_and_beats_round_robin() {
    // One instance saturates around ~1k inf/s; 1.5x that across a
    // fleet of 4 leaves the fleet under-committed.  Round-robin keeps
    // every instance lukewarm; packing concentrates the load so the
    // tail sleeps whole windows past break-even.
    let models = homogeneous(4);
    let prof = profile(1500.0, 0.1);
    let run = |dispatch| {
        let spec = FleetSpec {
            instances: 4,
            policy: dispatch,
            ..FleetSpec::default()
        };
        simulate_fleet(&models, &prof, &policy(), &spec).unwrap()
    };
    let rr = run(DispatchPolicy::RoundRobin);
    let jsq = run(DispatchPolicy::Jsq);
    let packing = run(DispatchPolicy::Packing);

    assert!(
        packing.gated_off_instances >= 1,
        "packing gated off {} of 4 instances",
        packing.gated_off_instances
    );
    assert_eq!(
        rr.gated_off_instances, 0,
        "round-robin should keep every instance lukewarm"
    );
    assert!(
        packing.energy_uj_per_inference()
            < rr.energy_uj_per_inference(),
        "packing {} µJ/inf must beat round-robin {} µJ/inf",
        packing.energy_uj_per_inference(),
        rr.energy_uj_per_inference(),
    );
    // the policies are genuinely different strategies, not aliases
    assert_ne!(
        jsq.to_json().render(),
        packing.to_json().render(),
        "JSQ and packing produced identical runs"
    );
}

#[test]
fn rank_fleet_beats_the_homogeneous_round_robin_baseline() {
    // The acceptance pin: for a profile that saturates one instance
    // but not the fleet, the fleet DSE must find a mix and/or policy
    // strictly better than N copies of the single-design winner
    // under round-robin — and do so byte-identically, twice.
    let ev = Evaluator::new();
    let base = Scenario::default();
    let mut ex = Explorer::new(base.network.clone());
    ex.model.tech = base.tech.technology();
    let points = ex.sweep().unwrap();
    let front = Explorer::pareto(&points);
    let prof = profile(1500.0, 0.1);
    let spec = FleetSpec { instances: 4, ..FleetSpec::default() };

    // baseline: the serving-aware single-instance winner, cloned
    // across the fleet, dispatched round-robin
    let single = rank_for_traffic(
        &ev,
        &base,
        &front,
        std::slice::from_ref(&prof),
        &policy(),
    )
    .unwrap();
    let svc = ServiceModel::new(
        &ev,
        &single[0].point.scenario(&base),
        policy().max_batch,
    )
    .unwrap();
    let baseline = simulate_fleet(
        &vec![svc; 4],
        &prof,
        &policy(),
        &FleetSpec {
            policy: DispatchPolicy::RoundRobin,
            ..spec.clone()
        },
    )
    .unwrap();

    let winner =
        rank_fleet(&ev, &base, &front, &prof, &policy(), &spec)
            .unwrap();
    assert!(winner.feasible, "the fleet winner must meet the SLO");
    assert!(
        winner.report.energy_uj_per_inference()
            < baseline.energy_uj_per_inference(),
        "fleet DSE {} µJ/inf does not beat the homogeneous \
         round-robin baseline {} µJ/inf",
        winner.report.energy_uj_per_inference(),
        baseline.energy_uj_per_inference(),
    );
    let heterogeneous =
        winner.mix.windows(2).any(|w| !w[0].bit_eq(&w[1]));
    assert!(
        heterogeneous || winner.policy != DispatchPolicy::RoundRobin,
        "the winner must differ from the baseline in mix or policy"
    );

    // byte-identical across a full re-run of the ranking
    let again =
        rank_fleet(&ev, &base, &front, &prof, &policy(), &spec)
            .unwrap();
    assert_eq!(
        winner.report.to_json().render(),
        again.report.to_json().render(),
        "rank_fleet is not deterministic"
    );
    assert_eq!(winner.policy, again.policy);
}

#[test]
fn heterogeneous_fleets_carry_their_own_designs() {
    let ev = Evaluator::new();
    let base = Scenario::default();
    let other = base
        .clone()
        .into_builder()
        .organization_named("SMP")
        .build()
        .unwrap();
    let a = ServiceModel::new(&ev, &base, policy().max_batch).unwrap();
    let b = ServiceModel::new(&ev, &other, policy().max_batch).unwrap();
    let spec = FleetSpec { instances: 2, ..FleetSpec::default() };
    let report = simulate_fleet(
        &[a, b],
        &profile(2000.0, 0.02),
        &policy(),
        &spec,
    )
    .unwrap();
    assert!(report.conserves());
    assert_ne!(
        report.per_instance[0].design_label,
        report.per_instance[1].design_label,
        "per-instance design labels must reflect the mix"
    );
}

#[test]
fn shape_errors_are_typed_not_panics() {
    let models = homogeneous(2);
    let prof = profile(1000.0, 0.01);
    // model count must match the spec
    let spec = FleetSpec { instances: 3, ..FleetSpec::default() };
    assert!(
        simulate_fleet(&models, &prof, &policy(), &spec).is_err()
    );
    // degenerate shapes are rejected before the loop starts
    for bad in [
        FleetSpec { instances: 0, ..FleetSpec::default() },
        FleetSpec { instances: 2, min_active: 0, ..FleetSpec::default() },
        FleetSpec { instances: 2, min_active: 3, ..FleetSpec::default() },
        FleetSpec { scale_up_depth: 0, ..FleetSpec::default() },
    ] {
        assert!(
            simulate_fleet(&models, &prof, &policy(), &bad).is_err(),
            "{bad:?}"
        );
    }
}

#[test]
fn fleet_loop_builds_no_timelines_and_tracing_is_free() {
    let models = homogeneous(3);
    let prof = profile(2000.0, 0.05);
    let spec = FleetSpec {
        instances: 3,
        policy: DispatchPolicy::Packing,
        elastic: true,
        scale_up_depth: 4,
        min_active: 1,
    };

    let before = Timeline::build_count();
    let plain =
        simulate_fleet(&models, &prof, &policy(), &spec).unwrap();
    assert_eq!(
        Timeline::build_count(),
        before,
        "the fleet event loop built a Timeline"
    );

    let mut sink = TraceSink::new();
    let traced = simulate_fleet_traced(
        &models,
        &prof,
        &policy(),
        &spec,
        Some(&mut sink),
    )
    .unwrap();
    assert_eq!(
        Timeline::build_count(),
        before,
        "tracing the fleet loop built a Timeline"
    );
    assert_eq!(
        plain.to_json().render(),
        traced.to_json().render(),
        "tracing perturbed the report"
    );
    // the trace itself is non-trivial and deterministic
    let rendered = perfetto::render(&sink);
    assert!(rendered.contains("fleet"), "no fleet tracks in trace");
    let mut sink2 = TraceSink::new();
    simulate_fleet_traced(
        &models,
        &prof,
        &policy(),
        &spec,
        Some(&mut sink2),
    )
    .unwrap();
    assert_eq!(rendered, perfetto::render(&sink2));
}

#[test]
fn elastic_scaling_breathes_and_stays_conservative() {
    // bursty load against an elastic fleet: the active set must grow
    // past the floor, park again, and never lose a request
    let prof = TrafficProfile {
        pattern: ArrivalPattern::Bursty,
        ..profile(3000.0, 0.1)
    };
    let spec = FleetSpec {
        instances: 4,
        policy: DispatchPolicy::Jsq,
        elastic: true,
        scale_up_depth: 2,
        min_active: 1,
    };
    let report =
        simulate_fleet(&homogeneous(4), &prof, &policy(), &spec)
            .unwrap();
    assert!(report.conserves());
    assert!(report.scale_ups > 0, "elastic fleet never scaled up");
    assert!(
        report.peak_active > 1,
        "peak active never left the floor"
    );
    assert!(
        report.peak_active <= 4,
        "active set exceeded the fleet size"
    );
}
