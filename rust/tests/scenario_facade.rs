//! Facade equivalence suite: the `Scenario`/`Evaluator` API must be
//! **bit-identical** to the pre-refactor entry points
//! (`CapStoreArch::build_default` + `EnergyModel::evaluate_arch` +
//! `system_energy` + `EventSim::run`) for every organization × network ×
//! technology node — plus property tests for the Scenario TOML
//! round-trip and the ScenarioSet product.

use capstore::analysis::breakdown::EnergyModel;
use capstore::capsnet::CapsNetConfig;
use capstore::capstore::arch::{CapStoreArch, Organization};
use capstore::capstore::eventsim::EventSim;
use capstore::dse::{Explorer, MultiSweep};
use capstore::scenario::{
    DmaModel, Evaluator, GatingPolicy, Scenario, ScenarioSet, TechNode,
    DEFAULT_LOOKAHEAD_CYCLES,
};
use capstore::testing::{check, Config};

/// The golden test of the redesign: one facade, every axis combination,
/// zero drift.  6 organizations × {mnist, small} × 4 tech nodes = 48
/// full evaluations compared field by field at the bit level.
#[test]
fn evaluator_bit_identical_to_legacy_path_everywhere() {
    let ev = Evaluator::new();
    for cfg in CapsNetConfig::all() {
        for node in TechNode::all() {
            let mut model = EnergyModel::new(cfg.clone());
            model.tech = node.technology();
            for org in Organization::all() {
                let sc = Scenario::builder()
                    .network_config(cfg.clone())
                    .tech_node(node)
                    .organization(org)
                    .build()
                    .unwrap();
                let tag = sc.label();

                // legacy path: direct arch build + scattered calls
                let arch =
                    CapStoreArch::build_default(org, &model.req, &model.tech)
                        .unwrap();
                let legacy = model.evaluate_arch(&arch);
                let legacy_sys = model.system_energy(&arch);
                let legacy_event =
                    EventSim::new(&arch, &model.req, &model.cfg, &model.sim)
                        .run(&GatingPolicy {
                            lookahead_cycles: DEFAULT_LOOKAHEAD_CYCLES,
                        })
                        .unwrap();

                // facade path
                let e = ev.evaluate(&sc).unwrap();

                // the architecture itself is identical
                assert_eq!(e.architecture, arch, "{tag}: arch diverged");

                // analytical on-chip integration, bit for bit
                assert_eq!(
                    e.onchip.onchip_pj.to_bits(),
                    legacy.onchip_pj.to_bits(),
                    "{tag}: onchip_pj"
                );
                assert_eq!(
                    e.onchip.area_mm2.to_bits(),
                    legacy.area_mm2.to_bits(),
                    "{tag}: area_mm2"
                );
                assert_eq!(e.onchip.capacity_bytes, legacy.capacity_bytes);
                assert_eq!(e.onchip.per_macro.len(), legacy.per_macro.len());
                for (a, b) in e.onchip.per_macro.iter().zip(&legacy.per_macro)
                {
                    assert_eq!(
                        a.dynamic_pj.to_bits(),
                        b.dynamic_pj.to_bits(),
                        "{tag}: per-macro dynamic"
                    );
                    assert_eq!(
                        a.static_pj.to_bits(),
                        b.static_pj.to_bits(),
                        "{tag}: per-macro static"
                    );
                    assert_eq!(
                        a.wakeup_pj.to_bits(),
                        b.wakeup_pj.to_bits(),
                        "{tag}: per-macro wakeup"
                    );
                }
                for ((ka, ea), (kb, eb)) in
                    e.onchip.per_op_pj.iter().zip(&legacy.per_op_pj)
                {
                    assert_eq!(ka, kb, "{tag}: per-op kind order");
                    assert_eq!(
                        ea.to_bits(),
                        eb.to_bits(),
                        "{tag}: per-op energy"
                    );
                }

                // whole-system view
                assert_eq!(e.system.label, legacy_sys.label);
                assert_eq!(
                    e.system.accel_pj.to_bits(),
                    legacy_sys.accel_pj.to_bits(),
                    "{tag}: accel_pj"
                );
                assert_eq!(
                    e.system.onchip_pj.to_bits(),
                    legacy_sys.onchip_pj.to_bits(),
                    "{tag}: system onchip_pj"
                );
                assert_eq!(
                    e.system.offchip_pj.to_bits(),
                    legacy_sys.offchip_pj.to_bits(),
                    "{tag}: offchip_pj"
                );

                // event-level cross-check
                let event =
                    e.event.as_ref().expect("full evaluate runs event sim");
                assert_eq!(
                    event.static_pj.to_bits(),
                    legacy_event.static_pj.to_bits(),
                    "{tag}: event static"
                );
                assert_eq!(
                    event.wakeup_pj.to_bits(),
                    legacy_event.wakeup_pj.to_bits(),
                    "{tag}: event wakeup"
                );
                assert_eq!(event.transitions, legacy_event.transitions);
                assert_eq!(event.cycles, legacy_event.cycles);
                assert_eq!(
                    event.not_ready_cycles,
                    legacy_event.not_ready_cycles
                );
            }
        }
    }
}

/// The baseline (version a) must also match through the facade, at
/// every node.
#[test]
fn all_onchip_baseline_matches_legacy_at_every_node() {
    let ev = Evaluator::new();
    for node in TechNode::all() {
        let mut model = EnergyModel::new(CapsNetConfig::mnist());
        model.tech = node.technology();
        let legacy = model.all_onchip_baseline().unwrap();
        let sc = Scenario::builder().tech_node(node).build().unwrap();
        let facade = ev.all_onchip_baseline(&sc).unwrap();
        assert_eq!(facade.label, legacy.label);
        assert_eq!(facade.accel_pj.to_bits(), legacy.accel_pj.to_bits());
        assert_eq!(facade.onchip_pj.to_bits(), legacy.onchip_pj.to_bits());
        assert_eq!(facade.offchip_pj.to_bits(), legacy.offchip_pj.to_bits());
    }
}

/// Explorer/MultiSweep are delegating shims now; their output must
/// still match the pre-refactor baseline sweep bit for bit (the deeper
/// engine identity lives in tests/dse_parallel.rs — this pins the shim
/// layer itself).
#[test]
fn dse_shims_still_match_their_baseline() {
    let ex = Explorer::new(CapsNetConfig::small());
    let baseline = ex.sweep_baseline().unwrap();
    let through_facade = ex.sweep().unwrap();
    assert_eq!(baseline.len(), through_facade.len());
    for (b, f) in baseline.iter().zip(&through_facade) {
        assert!(b.bit_eq(f), "shim diverged: {b:?} vs {f:?}");
    }
}

#[test]
fn scenario_set_subsumes_multisweep_product() {
    let set = ScenarioSet::grand();
    let scenarios = set.scenarios();
    assert_eq!(scenarios.len(), set.num_scenarios());
    assert_eq!(scenarios.len(), MultiSweep::default().num_points());
    // canonical order: first scenario is the first network at the
    // oldest node, first organization, smallest bank count
    let first = &scenarios[0];
    assert_eq!(first.network.name, CapsNetConfig::names()[0]);
    assert_eq!(first.tech, TechNode::N65);
}

/// Property: Scenario → TOML → Scenario is the identity for every
/// registry network, node, organization, geometry, batch and lookahead.
#[test]
fn prop_scenario_toml_roundtrip() {
    let names = CapsNetConfig::names();
    check(Config::default().cases(64), |rng| {
        let sc = Scenario::builder()
            .network(rng.pick(&names))
            .tech_node(*rng.pick(&TechNode::all()))
            .organization(*rng.pick(&Organization::all()))
            .banks(*rng.pick(&[2u64, 4, 8, 16, 32, 64]))
            .sectors(*rng.pick(&[1u64, 2, 8, 16, 64, 256]))
            .batch(rng.range(1, 64))
            .lookahead(rng.range(0, 1024))
            .dma_model(*rng.pick(&DmaModel::all()))
            .dma_bandwidth(rng.range(1, 128))
            .build()
            .unwrap();
        let text = sc.to_toml();
        let back = Scenario::parse(&text).unwrap();
        assert_eq!(sc, back, "round-trip failed for:\n{text}");
    });
}

/// Property: the facade is deterministic — evaluating the same scenario
/// twice (cold and warm caches) yields bit-identical numbers.
#[test]
fn prop_facade_is_cache_transparent() {
    let names = CapsNetConfig::names();
    let warm = Evaluator::new();
    check(Config::default().cases(12), |rng| {
        let sc = Scenario::builder()
            .network(rng.pick(&names))
            .tech_node(*rng.pick(&TechNode::all()))
            .organization(*rng.pick(&Organization::all()))
            .banks(*rng.pick(&[4u64, 8, 16]))
            .sectors(*rng.pick(&[8u64, 64]))
            .build()
            .unwrap();
        let cold = Evaluator::new().evaluate(&sc).unwrap();
        let cached = warm.evaluate(&sc).unwrap();
        assert_eq!(
            cold.onchip.onchip_pj.to_bits(),
            cached.onchip.onchip_pj.to_bits()
        );
        assert_eq!(
            cold.onchip.area_mm2.to_bits(),
            cached.onchip.area_mm2.to_bits()
        );
        assert_eq!(
            cold.system.offchip_pj.to_bits(),
            cached.system.offchip_pj.to_bits()
        );
        assert_eq!(
            cold.event.as_ref().map(|e| e.transitions),
            cached.event.as_ref().map(|e| e.transitions)
        );
    });
}
