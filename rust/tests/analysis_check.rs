//! Integration tests for the static diagnostics engine: `capstore
//! check` over the broken fixtures in `tests/fixtures/`, the registry
//! coverage invariant, the Timeline-free guarantee, and the admissible
//! property (check-pass implies the evaluator succeeds).
//!
//! Each `capXXX_*.toml` fixture triggers exactly one diagnostic code;
//! CAP005 and CAP013 have no static fixture because their triggers
//! depend on the derived break-even point, so they are exercised
//! programmatically from `analysis::check::scenario_bounds`.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::Command as Proc;

use capstore::analysis::check::{check_scenario, scenario_bounds};
use capstore::analysis::diag;
use capstore::config::toml::TomlDoc;
use capstore::dse::SweepSpace;
use capstore::fleet::FleetSpec;
use capstore::scenario::{Evaluator, Scenario};
use capstore::timeline::Timeline;
use capstore::traffic::TrafficProfile;
use capstore::util::json::Json;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn examples_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

/// Run `capstore check <file> --format json`; return (exit ok, doc).
fn check_subprocess(path: &Path) -> (bool, Json) {
    let out = Proc::new(env!("CARGO_BIN_EXE_capstore"))
        .args(["check", path.to_str().unwrap(), "--format", "json"])
        .output()
        .expect("spawn capstore");
    let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
    let doc = Json::parse(&stdout).unwrap_or_else(|e| {
        panic!("check {}: bad JSON ({e:?}):\n{stdout}", path.display())
    });
    (out.status.success(), doc)
}

/// Every diagnostic code in a `check` JSON document, in emission order.
fn emitted_codes(doc: &Json) -> Vec<String> {
    let mut codes = Vec::new();
    if let Some(Json::Arr(scenarios)) = doc.get("scenarios") {
        for sc in scenarios {
            if let Some(Json::Arr(diags)) = sc.get("diagnostics") {
                for d in diags {
                    if let Some(Json::Str(code)) = d.get("code") {
                        codes.push(code.clone());
                    }
                }
            }
        }
    }
    codes
}

/// Load a fixture the way `capstore check <file>` does, returning the
/// (scenario, doc) pair so CAP002's written-key rules can fire.
fn load(path: &Path) -> (Scenario, TomlDoc) {
    let text = std::fs::read_to_string(path).unwrap();
    let doc = TomlDoc::parse(&text).unwrap();
    let sc = Scenario::builder()
        .overlay_toml(&doc)
        .unwrap()
        .build()
        .unwrap();
    (sc, doc)
}

#[test]
fn fixtures_emit_their_codes_with_the_right_exit_status() {
    // (fixture, code it must emit, error severity => nonzero exit)
    let cases = [
        ("cap001_quantized_geometry.toml", "CAP001", false),
        ("cap002_ignored_keys.toml", "CAP002", false),
        ("cap003_infeasible_slo.toml", "CAP003", true),
        ("cap004_overload.toml", "CAP004", false),
        ("cap006_drop_everything.toml", "CAP006", true),
        ("cap007_inert_faults.toml", "CAP007", false),
        ("cap008_empty_window.toml", "CAP008", false),
        ("cap009_short_lookahead.toml", "CAP009", false),
        ("cap010_wake_watchdog.toml", "CAP010", false),
        ("cap012_fleet_overload.toml", "CAP012", true),
    ];
    for (file, code, is_error) in cases {
        let (ok, doc) = check_subprocess(&fixture_dir().join(file));
        let codes = emitted_codes(&doc);
        assert!(
            codes.iter().any(|c| c == code),
            "{file}: expected {code}, got {codes:?}"
        );
        assert_eq!(
            ok, !is_error,
            "{file}: exit status disagrees with severity ({codes:?})"
        );
        // fixtures are single-purpose: nothing but the target code fires
        assert!(
            codes.iter().all(|c| c == code),
            "{file}: stray diagnostics besides {code}: {codes:?}"
        );
    }
}

#[test]
fn cap005_fires_when_the_idle_gap_is_below_break_even() {
    // The trigger rate depends on the derived break-even point, so this
    // case is programmatic: pick a rate whose mean idle gap lands at
    // exactly half the break-even window.
    let base = Scenario::default();
    let (timing, gb) = scenario_bounds(&base).unwrap();
    let be = gb.break_even_cycles.expect("default organization is gated");
    let inter_arrival = timing.service_cycles as f64 + be as f64 / 2.0;
    let sc = Scenario {
        traffic: Some(TrafficProfile {
            rate_per_sec: timing.clock_hz / inter_arrival,
            duration_secs: 1.0,
            slo_ms: 1.0e3,
            ..Default::default()
        }),
        ..base
    };
    let report = check_scenario(&sc, None).unwrap();
    let codes: Vec<&str> = report.diagnostics.iter().map(|d| d.code).collect();
    assert!(codes.contains(&"CAP005"), "{codes:?}");
    assert!(report.passed(), "CAP005 is a warning, not an error");
}

/// CAP013 trigger scenario: an elastic fleet whose simulated window is
/// shorter than the fleet-wide break-even budget, sized from the
/// derived bounds so no error-severity code co-fires.
fn short_elastic_window() -> Scenario {
    let base = Scenario::default();
    let (timing, gb) = scenario_bounds(&base).unwrap();
    let be = gb.break_even_cycles.expect("default organization is gated");
    // instances^2 >= 4 * service / break_even keeps the arrival rate
    // needed to dodge CAP008 below the fleet capacity (no CAP012).
    let instances = 2
        * ((timing.service_cycles as f64 / be as f64).sqrt().ceil()
            as usize
            + 1);
    let budget = be as f64 * instances as f64;
    let horizon = budget / 2.0; // cycles: strictly inside the budget
    let duration_secs = horizon / timing.clock_hz;
    Scenario {
        traffic: Some(TrafficProfile {
            rate_per_sec: 2.0 / duration_secs, // two expected arrivals
            duration_secs,
            slo_ms: 1.0e3,
            ..Default::default()
        }),
        fleet: Some(FleetSpec {
            instances,
            elastic: true,
            min_active: 1,
            ..Default::default()
        }),
        ..base
    }
}

#[test]
fn cap013_fires_when_elastic_wakes_cannot_amortize() {
    let report = check_scenario(&short_elastic_window(), None).unwrap();
    let codes: Vec<&str> =
        report.diagnostics.iter().map(|d| d.code).collect();
    assert!(codes.contains(&"CAP013"), "{codes:?}");
    assert!(report.passed(), "CAP013 is a warning, not an error");
}

#[test]
fn fleet_scenarios_report_cap012_instead_of_cap004() {
    // The same overload that fires CAP004 standalone must fire CAP012
    // (and only CAP012) once a fleet is declared: the fleet-wide bound
    // supersedes the single-instance one.
    let overload = TrafficProfile {
        rate_per_sec: 5.0e4,
        slo_ms: 50.0,
        ..Default::default()
    };
    let solo = Scenario {
        traffic: Some(overload.clone()),
        ..Scenario::default()
    };
    let report = check_scenario(&solo, None).unwrap();
    assert!(report.diagnostics.iter().any(|d| d.code == "CAP004"));

    let fleet = Scenario {
        traffic: Some(overload),
        fleet: Some(FleetSpec { instances: 4, ..Default::default() }),
        ..Scenario::default()
    };
    let report = check_scenario(&fleet, None).unwrap();
    let codes: Vec<&str> =
        report.diagnostics.iter().map(|d| d.code).collect();
    assert!(codes.contains(&"CAP012"), "{codes:?}");
    assert!(!codes.contains(&"CAP004"), "{codes:?}");
    assert!(!report.passed(), "CAP012 is an error");
}

#[test]
fn every_registered_code_is_exercised() {
    let mut seen = BTreeSet::new();

    // every scenario fixture, through the library path
    for entry in std::fs::read_dir(fixture_dir()).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let (sc, doc) = load(&path);
        let report = check_scenario(&sc, Some(&doc)).unwrap();
        assert!(
            !report.diagnostics.is_empty(),
            "{}: a broken fixture produced no findings",
            path.display()
        );
        for d in &report.diagnostics {
            seen.insert(d.code.to_string());
        }
    }

    // CAP005: programmatic (see cap005_fires_when_...)
    let base = Scenario::default();
    let (timing, gb) = scenario_bounds(&base).unwrap();
    let be = gb.break_even_cycles.unwrap() as f64;
    let sc = Scenario {
        traffic: Some(TrafficProfile {
            rate_per_sec: timing.clock_hz
                / (timing.service_cycles as f64 + be / 2.0),
            duration_secs: 1.0,
            slo_ms: 1.0e3,
            ..Default::default()
        }),
        ..base
    };
    for d in check_scenario(&sc, None).unwrap().diagnostics {
        seen.insert(d.code.to_string());
    }

    // CAP013: programmatic (see cap013_fires_when_...)
    for d in check_scenario(&short_elastic_window(), None)
        .unwrap()
        .diagnostics
    {
        seen.insert(d.code.to_string());
    }

    // CAP011: space-scoped, no TOML surface
    let space = SweepSpace { banks: Vec::new(), ..SweepSpace::default() };
    for d in space.check() {
        seen.insert(d.code.to_string());
    }

    for spec in diag::CODES {
        assert!(
            seen.contains(spec.code),
            "registered code {} is never exercised by any fixture or \
             programmatic case",
            spec.code
        );
    }
}

#[test]
fn check_builds_no_timeline_and_admissible_scenarios_evaluate() {
    // Part 1 — the Timeline-free guarantee: checking an infeasible
    // scenario (static-floor SLO violation) rejects it without ever
    // constructing the timeline IR.  Both parts share one test function
    // because `Timeline::build_count` is process-wide and part 2 builds
    // timelines on purpose.
    let (sc, doc) = load(&fixture_dir().join("cap003_infeasible_slo.toml"));
    let before = Timeline::build_count();
    let report = check_scenario(&sc, Some(&doc)).unwrap();
    assert!(!report.passed());
    assert!(report.diagnostics.iter().any(|d| d.code == "CAP003"));
    assert_eq!(
        Timeline::build_count(),
        before,
        "check_scenario constructed a Timeline"
    );

    // Part 2 — the admissible property: a scenario the checker passes
    // (errors == 0; warnings are fine) must evaluate cleanly.
    let ev = Evaluator::new();
    for entry in std::fs::read_dir(examples_dir()).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let (sc, doc) = load(&path);
        let report = check_scenario(&sc, Some(&doc)).unwrap();
        assert!(
            report.diagnostics.is_empty(),
            "{}: examples must be finding-free, got {:?}",
            path.display(),
            report.diagnostics
        );
        ev.evaluate(&sc).unwrap_or_else(|e| {
            panic!(
                "{}: passed check but failed evaluation: {e:?}",
                path.display()
            )
        });
    }
    // and across the organization axis (analytical path, for speed)
    for org in capstore::capstore::arch::Organization::all() {
        let sc = Scenario { organization: org, ..Scenario::default() };
        let report = check_scenario(&sc, None).unwrap();
        if report.passed() {
            ev.evaluate_analytical(&sc).unwrap_or_else(|e| {
                panic!("{}: passed check but failed evaluation: {e:?}",
                       org.label())
            });
        }
    }
}

#[test]
fn all_examples_mode_is_clean() {
    // cwd of an integration test is the crate root (rust/), so the
    // command resolves the repo-root examples/ via its ../ fallback
    let out = Proc::new(env!("CARGO_BIN_EXE_capstore"))
        .args(["check", "--all-examples", "--format", "json"])
        .output()
        .expect("spawn capstore");
    assert!(
        out.status.success(),
        "check --all-examples failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let doc = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(doc.get("errors"), Some(&Json::Num(0.0)));
    assert_eq!(doc.get("warnings"), Some(&Json::Num(0.0)));
    match doc.get("checked") {
        Some(&Json::Num(n)) => assert!(n >= 3.0, "only {n} examples"),
        other => panic!("bad `checked` field: {other:?}"),
    }
}
