//! Golden-file CLI tests + registry invariants.
//!
//! The golden tests pin the `--format json` output of `evaluate`,
//! `timeline`, and `traffic` for one fixed scenario, byte for byte.
//! Each case is run twice (determinism) and compared against
//! `tests/golden/<name>.json`; a missing golden file is written on
//! first run (and `CAPSTORE_BLESS=1 cargo test` re-blesses after an
//! intentional output change — the diff then shows up in review).
//!
//! The registry invariants assert the self-describing property the CLI
//! redesign is built on: every flag of every command carries a doc
//! string and appears in `capstore help <cmd>`, and the generated
//! usage/completions cover the whole registry.

use std::path::{Path, PathBuf};
use std::process::Command as Proc;

use capstore::cli::{completions, help, registry};
use capstore::util::json::Json;

/// Run the release/test binary, asserting success and non-empty stdout.
fn run_capstore(args: &[&str]) -> String {
    let out = Proc::new(env!("CARGO_BIN_EXE_capstore"))
        .args(args)
        .output()
        .expect("spawn capstore");
    assert!(
        out.status.success(),
        "capstore {:?} failed: {}",
        args,
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
    assert!(!stdout.is_empty(), "capstore {args:?}: empty stdout");
    stdout
}

fn golden_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(format!("{name}.json"))
}

/// Determinism + golden comparison for one `--format json` invocation.
fn golden_check(name: &str, args: &[&str], required_keys: &[&str]) {
    let out1 = run_capstore(args);
    let out2 = run_capstore(args);
    assert_eq!(out1, out2, "non-deterministic output for {args:?}");

    // structural sanity independent of the golden file: parses as a
    // JSON object and carries the expected top-level keys
    let doc = Json::parse(&out1).expect("stdout is one JSON document");
    for key in required_keys {
        assert!(
            doc.get(key).is_some(),
            "{name}: missing top-level key {key:?}"
        );
    }

    let path = golden_path(name);
    let bless = std::env::var_os("CAPSTORE_BLESS").is_some();
    if bless || !path.exists() {
        // Bootstrap: the authoring container has no Rust toolchain, so
        // golden files materialize on the first toolchain-ed run and
        // must then be committed (see tests/golden/README.md).  Until
        // they are, only the determinism + key checks above bite; set
        // CAPSTORE_REQUIRE_GOLDEN=1 to turn a missing golden into a
        // hard failure instead of a re-bless.
        assert!(
            bless || std::env::var_os("CAPSTORE_REQUIRE_GOLDEN").is_none(),
            "{name}: golden file {} is missing and \
             CAPSTORE_REQUIRE_GOLDEN is set — generate it with \
             CAPSTORE_BLESS=1 cargo test and commit it",
            path.display()
        );
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &out1).unwrap();
        eprintln!(
            "{name}: blessed {} — commit it to pin this output",
            path.display()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        out1, want,
        "{name}: output drifted from {}; if intentional, re-bless with \
         CAPSTORE_BLESS=1 cargo test",
        path.display()
    );
}

#[test]
fn golden_evaluate_json() {
    golden_check(
        "evaluate",
        &["evaluate", "--model", "mnist", "--tech", "32nm", "--format",
          "json"],
        &["table1", "table2", "systems", "selected"],
    );
}

#[test]
fn golden_timeline_json() {
    golden_check(
        "timeline",
        &["timeline", "mnist", "PG-SEP", "--format", "json"],
        &["scenario", "ops", "gating_segments", "total_cycles"],
    );
}

#[test]
fn golden_traffic_json() {
    golden_check(
        "traffic",
        &["traffic", "mnist", "PG-SEP", "--rate", "500", "--seed", "7",
          "--format", "json"],
        &["scenario", "profile", "arrivals", "served"],
    );
}

#[test]
fn golden_check_json() {
    golden_check(
        "check",
        &["check", "--model", "mnist", "--tech", "32nm", "--format",
          "json"],
        &["checked", "errors", "warnings", "scenarios"],
    );
}

#[test]
fn golden_dse_json_has_no_wall_clock() {
    golden_check(
        "dse",
        &["dse", "--model", "mnist", "--tech", "32nm", "--threads", "1",
          "--format", "json"],
        &["network", "tech", "points", "pareto_front", "best"],
    );
    // regression for the wall-clock leak: the JSON document used to
    // carry a `seconds` field measured with Instant::now(), making
    // `--format json` non-reproducible run to run
    let out = run_capstore(&["dse", "--model", "mnist", "--tech", "32nm",
                             "--threads", "1", "--format", "json"]);
    let doc = Json::parse(&out).expect("dse JSON parses");
    assert!(
        doc.get("seconds").is_none(),
        "dse JSON leaks wall-clock timing"
    );
}

#[test]
fn unknown_subcommand_fails_with_suggestion() {
    // the satellite bugfix: `capstore frobnicate --x 1` used to parse
    // fine and only die in the dispatcher; a near-miss now gets a
    // registry-derived suggestion on stderr
    let out = Proc::new(env!("CARGO_BIN_EXE_capstore"))
        .args(["trafic", "--rate", "5"])
        .output()
        .expect("spawn capstore");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown subcommand"), "{stderr}");
    assert!(stderr.contains("did you mean `traffic`"), "{stderr}");

    let out = Proc::new(env!("CARGO_BIN_EXE_capstore"))
        .args(["frobnicate", "--x", "1"])
        .output()
        .expect("spawn capstore");
    assert!(!out.status.success());
}

#[test]
fn help_and_completions_run() {
    let usage = run_capstore(&["help"]);
    for cmd in registry::commands() {
        assert!(usage.contains(cmd.name()), "usage misses {}", cmd.name());
    }
    let all = run_capstore(&["help", "--all"]);
    assert_eq!(all.trim_end(), help::reference());
    let bash = run_capstore(&["completions", "bash"]);
    assert_eq!(bash.trim_end(), completions::bash());
    let zsh = run_capstore(&["completions", "zsh"]);
    assert_eq!(zsh.trim_end(), completions::zsh());
}

#[test]
fn registry_invariants_every_flag_documented_and_in_help() {
    for cmd in registry::commands() {
        let h = help::command_help(*cmd);
        let flags = cmd.flags();
        // names unique within the command
        let mut names: Vec<&str> = flags.iter().map(|f| f.name).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(
            names.len(),
            before,
            "`{}` lists a flag twice",
            cmd.name()
        );
        for f in flags {
            assert!(
                !f.doc.trim().is_empty(),
                "--{} of `{}` has no doc string",
                f.name,
                cmd.name()
            );
            assert!(
                h.contains(&format!("--{}", f.name)),
                "`capstore help {}` does not mention --{}",
                cmd.name(),
                f.name
            );
            assert!(
                f.hint.is_empty() == !f.kind.takes_value(),
                "--{} of `{}`: value-taking flags need a hint, \
                 switches must not have one",
                f.name,
                cmd.name()
            );
        }
    }
}

#[test]
fn registry_invariants_generated_surfaces_cover_everything() {
    let usage = help::usage();
    let reference = help::reference();
    let bash = completions::bash();
    let zsh = completions::zsh();
    for cmd in registry::commands() {
        for surface in [&usage, &reference, &bash, &zsh] {
            assert!(
                surface.contains(cmd.name()),
                "a generated surface misses command {}",
                cmd.name()
            );
        }
        for f in cmd.flags() {
            for surface in [&reference, &bash, &zsh] {
                assert!(
                    surface.contains(&format!("--{}", f.name)),
                    "a generated surface misses --{} of {}",
                    f.name,
                    cmd.name()
                );
            }
        }
    }
}
