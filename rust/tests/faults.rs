//! End-to-end invariants of the deterministic fault-injection layer:
//!
//! 1. **identity transparency** — the all-zero [`FaultPlan`] plus the
//!    do-nothing [`ResiliencePolicy`] leaves every existing report
//!    bit-identical: traffic JSON across seeds/patterns/networks,
//!    timeline totals across organizations, and serving-aware DSE
//!    ranks;
//! 2. **determinism under faults** — the same seeded plan renders
//!    byte-identical JSON across two invocations;
//! 3. **conservation** — every request copy ends in exactly one bucket
//!    under combined queue faults and resilience;
//! 4. **the pinned SLO flip** — at a high wake-failure rate the gated
//!    design loses SLO-feasibility, and the all-on fallback policy
//!    restores it (the DESCNet break-even rule extended to a
//!    reliability regime).

use std::time::Duration;

use capstore::analysis::breakdown::EnergyModel;
use capstore::capsnet::CapsNetConfig;
use capstore::capstore::arch::{CapStoreArch, Organization};
use capstore::coordinator::BatchPolicy;
use capstore::dse::Explorer;
use capstore::faults::{FaultPlan, ResiliencePolicy};
use capstore::scenario::{Evaluator, Scenario};
use capstore::timeline::{DmaModel, DmaPolicy, Timeline, TimelinePolicy};
use capstore::traffic::{
    rank_for_traffic, rank_for_traffic_under, simulate, simulate_with,
    ArrivalPattern, ServiceModel, TrafficProfile, SLO_MISS_BUDGET,
};

fn policy(max_batch: usize) -> BatchPolicy {
    BatchPolicy { max_batch, max_wait: Duration::from_millis(2) }
}

fn assert_conserved(r: &capstore::traffic::TrafficReport, tag: &str) {
    let s = &r.resilience;
    assert_eq!(
        r.arrivals + s.duplicated + s.retried,
        r.served + r.queued + s.shed + s.dropped + s.timed_out,
        "{tag}: copy conservation broken: {s:?}"
    );
}

#[test]
fn identity_plan_leaves_traffic_reports_bit_identical() {
    // property: across networks, seeds, and arrival patterns, the
    // identity injection path renders the same bytes as the plain one
    let ev = Evaluator::new();
    for cfg in CapsNetConfig::all() {
        let sc = Scenario { network: cfg.clone(), ..Scenario::default() };
        let svc = ServiceModel::new(&ev, &sc, 4).unwrap();
        for seed in [1u64, 7, 1234] {
            for pattern in ArrivalPattern::all() {
                let p = TrafficProfile {
                    pattern,
                    rate_per_sec: 2000.0,
                    seed,
                    duration_secs: 0.02,
                    slo_ms: 10.0,
                };
                let plain = simulate(&svc, &p, &policy(4)).unwrap();
                let injected = simulate_with(
                    &svc,
                    &p,
                    &policy(4),
                    &FaultPlan::none(),
                    &ResiliencePolicy::none(),
                )
                .unwrap();
                assert_eq!(
                    plain.to_json(svc.clock_hz).render(),
                    injected.to_json(svc.clock_hz).render(),
                    "{} seed {seed} {pattern:?}: identity not transparent",
                    cfg.name
                );
            }
        }
    }
}

#[test]
fn identity_plan_leaves_timeline_totals_bit_identical() {
    let model = EnergyModel::new(CapsNetConfig::mnist());
    let ctx = model.context();
    for org in Organization::all() {
        let arch =
            CapStoreArch::build_default(org, &model.req, &model.tech)
                .unwrap();
        let policy = TimelinePolicy::default();
        let base = Timeline::build(&ctx, &arch, &model.req, &policy);
        let id = Timeline::build_with_faults(
            &ctx,
            &arch,
            &model.req,
            &policy,
            &FaultPlan::none(),
        );
        let tag = org.label();
        assert_eq!(base.total_cycles, id.total_cycles, "{tag}");
        assert_eq!(base.not_ready_cycles, id.not_ready_cycles, "{tag}");
        assert_eq!(base.domains, id.domains, "{tag}: segments diverged");
        assert_eq!(
            base.static_pj().to_bits(),
            id.static_pj().to_bits(),
            "{tag}: static energy"
        );
        assert_eq!(
            base.wakeup_pj().to_bits(),
            id.wakeup_pj().to_bits(),
            "{tag}: wakeup energy"
        );
        assert_eq!(id.failed_wakes(), 0, "{tag}");
        assert_eq!(id.failed_wake_pj().to_bits(), 0f64.to_bits(), "{tag}");
    }
}

#[test]
fn identity_plan_leaves_dse_ranks_identical() {
    let ex = Explorer::new(CapsNetConfig::mnist());
    let front = Explorer::pareto(&ex.sweep().unwrap());
    let ev = Evaluator::new();
    let base = Scenario::default();
    let svc0 = ServiceModel::new(&ev, &base, 8).unwrap();
    let capacity = svc0.clock_hz / svc0.per_batch[0].latency_cycles as f64;
    let profiles: Vec<TrafficProfile> = [0.01, 2.0]
        .iter()
        .map(|&frac| TrafficProfile {
            pattern: ArrivalPattern::Poisson,
            rate_per_sec: frac * capacity,
            seed: 7,
            duration_secs: 200.0 / (frac * capacity),
            slo_ms: 1.0e6,
        })
        .collect();
    let plain =
        rank_for_traffic(&ev, &base, &front, &profiles, &policy(8))
            .unwrap();
    let injected = rank_for_traffic_under(
        &ev,
        &base,
        &front,
        &profiles,
        &policy(8),
        &FaultPlan::none(),
        &ResiliencePolicy::none(),
    )
    .unwrap();
    assert_eq!(plain.len(), injected.len());
    for (a, b) in plain.iter().zip(&injected) {
        assert!(
            a.point.bit_eq(&b.point),
            "identity plan moved a winner: {:?} vs {:?}",
            a.point,
            b.point
        );
        assert_eq!(a.feasible, b.feasible);
        assert_eq!(
            a.report.to_json(svc0.clock_hz).render(),
            b.report.to_json(svc0.clock_hz).render(),
            "winner report diverged under the identity plan"
        );
    }
}

#[test]
fn active_faults_are_byte_identical_across_invocations() {
    // a serial-DMA scenario so the degradation windows have a table to
    // re-price from, plus every other fault class and an active policy
    let sc = Scenario {
        dma: DmaPolicy {
            model: DmaModel::Serial,
            bandwidth_bytes_per_cycle: 16,
        },
        ..Scenario::default()
    };
    let faults = FaultPlan {
        seed: 99,
        wake_fail_rate: 0.3,
        dma_degrade_rate: 0.3,
        dma_degrade_dwell_secs: 0.005,
        slowdown_rate: 0.3,
        slowdown_dwell_secs: 0.005,
        drop_rate: 0.05,
        duplicate_rate: 0.05,
        ..FaultPlan::none()
    };
    let resilience = ResiliencePolicy {
        queue_cap: Some(64),
        timeout_ms: Some(5.0),
        retry_budget: 2,
        wake_fail_fallback: Some(0.9),
        degraded_max_batch: Some(2),
    };
    let ev = Evaluator::new();
    let svc =
        ServiceModel::with_faults(&ev, &sc, 4, Some(&faults)).unwrap();
    assert!(
        svc.per_batch_degraded.is_some(),
        "serial DMA + degrade rate must build the degraded table"
    );
    let p = TrafficProfile {
        pattern: ArrivalPattern::Bursty,
        rate_per_sec: 4000.0,
        seed: 3,
        duration_secs: 0.05,
        slo_ms: 5.0,
    };
    let run = || {
        simulate_with(&svc, &p, &policy(4), &faults, &resilience).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(
        a.to_json(svc.clock_hz).render(),
        b.to_json(svc.clock_hz).render(),
        "same seed, same plan: reports diverged"
    );
    assert!(a.resilience_active);
    assert_conserved(&a, "combined faults");
    // a different fault seed perturbs the run
    let other = simulate_with(
        &svc,
        &p,
        &policy(4),
        &FaultPlan { seed: 100, ..faults.clone() },
        &resilience,
    )
    .unwrap();
    assert_ne!(
        a.to_json(svc.clock_hz).render(),
        other.to_json(svc.clock_hz).render(),
        "fault seed is ignored"
    );
}

#[test]
fn gated_design_loses_slo_feasibility_to_the_all_on_fallback() {
    // The pinned acceptance scenario.  A gated design at trickle load
    // sleeps between requests, so every dispatch wakes cold; at a 0.9
    // wake-failure rate most cold starts burn through retries and blow
    // a 2x-service-time SLO.  Without resilience the design is
    // SLO-infeasible.  The all-on fallback observes the failure rate,
    // stops gating, and the rest of the run serves warm at nominal
    // latency — feasible again, at the cost of idle leakage.
    let ev = Evaluator::new();
    let sc = Scenario::default();
    let svc = ServiceModel::new(&ev, &sc, 1).unwrap();
    assert!(svc.gated, "the pinned scenario must gate");
    let service = svc.per_batch[0].latency_cycles;
    let faults = FaultPlan {
        wake_fail_rate: 0.9,
        max_wake_retries: 3,
        // one service time per watchdog window: the first retry already
        // doubles the request latency
        wake_timeout_cycles: service,
        ..FaultPlan::none()
    };
    // mean gap 8x the fault-extended break-even point: essentially
    // every dispatch sleeps first, whatever the absolute numbers are
    let gap = svc.break_even_cycles_under(&faults).unwrap() * 8;
    let rate = svc.clock_hz / gap as f64;
    let profile = TrafficProfile {
        pattern: ArrivalPattern::Poisson,
        rate_per_sec: rate,
        seed: 5,
        // ~400 arrivals: a handful of pre-fallback misses cannot break
        // the 1% budget on their own
        duration_secs: 400.0 / rate,
        slo_ms: 2.0 * service as f64 / svc.clock_hz * 1.0e3,
    };
    let pol = policy(1);

    let stubborn = simulate_with(
        &svc,
        &profile,
        &pol,
        &faults,
        &ResiliencePolicy::none(),
    )
    .unwrap();
    assert!(stubborn.served > 200, "trickle run served too little");
    assert!(stubborn.cold_starts > 100, "trickle load stayed warm");
    assert!(
        stubborn.slo_violation_fraction() > SLO_MISS_BUDGET,
        "wake failures at 0.9 left the gated design feasible \
         ({} violations / {} served)",
        stubborn.slo_violations,
        stubborn.served
    );

    let graceful = simulate_with(
        &svc,
        &profile,
        &pol,
        &faults,
        &ResiliencePolicy {
            wake_fail_fallback: Some(0.25),
            ..ResiliencePolicy::none()
        },
    )
    .unwrap();
    let at = graceful
        .resilience
        .fallback_at_cycle
        .expect("0.9 failure rate must engage the fallback");
    assert!(
        graceful.slo_violation_fraction() <= SLO_MISS_BUDGET,
        "the all-on fallback did not restore feasibility \
         ({} violations / {} served, fallback at {at})",
        graceful.slo_violations,
        graceful.served
    );
    // the flip is the point: same design, same faults — the policy is
    // what separates infeasible from feasible
    assert!(graceful.cold_starts < stubborn.cold_starts);
    assert!(
        graceful.resilience.wake_failures
            < stubborn.resilience.wake_failures
    );
    // and the reliability is bought with leakage, not magic: holding
    // the memory awake costs more idle energy than gated sleep would
    assert!(graceful.idle_pj > stubborn.idle_pj);
    assert_conserved(&stubborn, "stubborn");
    assert_conserved(&graceful, "graceful");
}

#[test]
fn fault_plan_toml_round_trips_through_the_scenario() {
    // [faults] in scenario TOML: parse -> to_toml -> parse is exact
    let sc = Scenario {
        faults: Some(FaultPlan {
            seed: 17,
            wake_fail_rate: 0.25,
            drop_rate: 0.01,
            ..FaultPlan::none()
        }),
        ..Scenario::default()
    };
    let text = sc.to_toml();
    let back = Scenario::parse(&text).unwrap();
    assert_eq!(back.faults, sc.faults);
    let again = Scenario::parse(&back.to_toml()).unwrap();
    assert_eq!(again, back);
}
