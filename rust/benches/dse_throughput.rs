//! Bench: throughput of the parallel incremental DSE engine on the
//! enlarged sweep space, against the pre-refactor serial baseline
//! (per-point context rebuild + uncached CACTI).
//!
//! Reports JSON on the last line so CI and scripts can consume it:
//!
//! ```json
//! {"bench":"dse_throughput","points":273,...,"points_per_sec":...}
//! ```
//!
//! Modes:
//!   (default)   measure + print JSON
//!   --check     CI mode: additionally assert the engine speedup —
//!               >= 2x end-to-end on machines with >= 4 cores (skips
//!               the assertion, not the run, on smaller machines)
//!   --threads N worker override (0 = all cores)
//!
//! Before timing anything the bench verifies the parallel sweep is
//! bit-identical to the serial one; a determinism violation fails the
//! bench outright.

use capstore::bench;
use capstore::capsnet::CapsNetConfig;
use capstore::dse::{Explorer, MultiSweep, SweepSpace};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let check = args.iter().any(|a| a == "--check");
    let threads: usize = args
        .iter()
        .position(|a| a == "--threads")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let mut ex = Explorer::new(CapsNetConfig::mnist()).with_threads(threads);
    ex.space = SweepSpace::large();
    let points = ex.space.num_points();

    // ---- determinism gate (before any timing) -------------------------
    let serial = ex.sweep_serial().expect("serial sweep");
    let parallel = ex.sweep().expect("parallel sweep");
    assert_eq!(serial.len(), parallel.len());
    for (i, (s, p)) in serial.iter().zip(&parallel).enumerate() {
        assert!(
            s.bit_eq(p),
            "determinism violation at point {i}: {s:?} vs {p:?}"
        );
    }
    println!(
        "[dse_throughput] determinism: {} parallel points bit-identical \
         to serial",
        points
    );

    // ---- timings ------------------------------------------------------
    let t_baseline =
        bench::bench("dse: baseline (per-point ctx, uncached, serial)", 1, 7, || {
            std::hint::black_box(ex.sweep_baseline().unwrap());
        });
    let t_serial =
        bench::bench("dse: engine serial (shared ctx + cost cache)", 1, 7, || {
            std::hint::black_box(ex.sweep_serial().unwrap());
        });
    let t_parallel = bench::bench("dse: engine parallel", 1, 7, || {
        std::hint::black_box(ex.sweep().unwrap());
    });

    // grand sweep throughput: models x tech nodes x large space
    let ms = MultiSweep { threads, ..MultiSweep::default() };
    let grand_points = ms.num_points();
    let t_grand = bench::bench("dse: grand sweep (models x tech nodes)", 1, 3, || {
        std::hint::black_box(ms.run().unwrap());
    });

    let ctx_speedup = t_baseline.median / t_serial.median.max(1e-9);
    let par_speedup = t_serial.median / t_parallel.median.max(1e-9);
    let end_to_end = t_baseline.median / t_parallel.median.max(1e-9);
    let pps = points as f64 / (t_parallel.median / 1.0e3).max(1e-12);
    let grand_pps =
        grand_points as f64 / (t_grand.median / 1.0e3).max(1e-12);

    println!(
        "\n[dse_throughput] {points} points: baseline {:.2} ms -> serial \
         {:.2} ms ({ctx_speedup:.2}x) -> parallel {:.2} ms \
         ({par_speedup:.2}x more, {end_to_end:.2}x end-to-end) on {cores} \
         cores",
        t_baseline.median, t_serial.median, t_parallel.median
    );
    println!(
        "[dse_throughput] grand sweep: {grand_points} points in {:.2} ms \
         ({grand_pps:.0} points/s)",
        t_grand.median
    );

    // machine-readable result (last line)
    println!(
        "{{\"bench\":\"dse_throughput\",\"points\":{points},\
         \"grand_points\":{grand_points},\"cores\":{cores},\
         \"threads\":{threads},\
         \"baseline_ms\":{:.4},\"serial_ms\":{:.4},\"parallel_ms\":{:.4},\
         \"grand_ms\":{:.4},\"ctx_cache_speedup\":{ctx_speedup:.3},\
         \"parallel_speedup\":{par_speedup:.3},\
         \"end_to_end_speedup\":{end_to_end:.3},\
         \"points_per_sec\":{pps:.0},\"grand_points_per_sec\":{grand_pps:.0}}}",
        t_baseline.median, t_serial.median, t_parallel.median, t_grand.median
    );

    if check {
        if cores >= 4 {
            assert!(
                end_to_end >= 2.0,
                "check failed: end-to-end speedup {end_to_end:.2}x < 2x \
                 on {cores} cores"
            );
            println!(
                "dse_throughput check OK ({end_to_end:.2}x >= 2x on \
                 {cores} cores)"
            );
        } else {
            println!(
                "dse_throughput check SKIPPED (only {cores} cores; need \
                 >= 4 for the speedup assertion)"
            );
        }
    }
}
