//! Bench: the traffic-driven serving simulator — throughput of the
//! event loop, plus the two contracts CI enforces in `--check` mode:
//!
//! * **determinism** — two runs of the same seeded profile produce
//!   byte-identical `TrafficReport` JSON (no wall clock, no ambient
//!   randomness anywhere in the loop);
//! * **hot path** — the simulator builds zero `Timeline` IRs per
//!   dispatched batch: the per-batch-size energy/latency table is
//!   precomputed in `ServiceModel::new` and cached (mirroring the
//!   `timeline_build` bench's guard for the DSE sweep).
//!
//! Reports JSON on the last line:
//!
//! ```json
//! {"bench":"traffic_sim","sim_ms":...,"hot_path_timeline_builds":0,...}
//! ```

use std::time::Duration;

use capstore::bench;
use capstore::coordinator::BatchPolicy;
use capstore::faults::{FaultPlan, ResiliencePolicy};
use capstore::scenario::{Evaluator, Scenario};
use capstore::timeline::Timeline;
use capstore::traffic::{
    simulate, simulate_with, ArrivalPattern, ServiceModel, TrafficProfile,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let check = args.iter().any(|a| a == "--check");

    let ev = Evaluator::new();
    let sc = Scenario::default();
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
    };

    // ---- amortized part: the per-batch-size service table ------------
    let t_model = bench::bench("traffic: ServiceModel::new (8 sizes)", 1, 5, || {
        std::hint::black_box(
            ServiceModel::new(&ev, &sc, policy.max_batch)
                .expect("service model"),
        );
    });
    let svc = ServiceModel::new(&ev, &sc, policy.max_batch).unwrap();

    let profile = TrafficProfile {
        pattern: ArrivalPattern::Poisson,
        rate_per_sec: 2000.0,
        seed: 7,
        duration_secs: 0.25,
        slo_ms: 10.0,
    };

    // ---- contracts ---------------------------------------------------
    let before = Timeline::build_count();
    let r1 = simulate(&svc, &profile, &policy).unwrap();
    let hot_builds = Timeline::build_count() - before;
    let r2 = simulate(&svc, &profile, &policy).unwrap();
    let j1 = r1.to_json(svc.clock_hz).render();
    let j2 = r2.to_json(svc.clock_hz).render();
    // identity fault injection must be bit-transparent: the same run
    // through simulate_with(identity, none) renders the same bytes
    let r0 = simulate_with(
        &svc,
        &profile,
        &policy,
        &FaultPlan::none(),
        &ResiliencePolicy::none(),
    )
    .unwrap();
    let identity_transparent = j1 == r0.to_json(svc.clock_hz).render();
    let deterministic = j1 == j2;

    // ---- event-loop throughput --------------------------------------
    let t_sim = bench::bench("traffic: simulate (poisson 2000/s x 0.25s)", 2, 9, || {
        std::hint::black_box(simulate(&svc, &profile, &policy).unwrap());
    });

    println!(
        "\n[traffic_sim] model {:.3} ms; sim {:.3} ms for {} arrivals \
         ({} served, {} batches); {hot_builds} timeline builds on the \
         dispatch path; deterministic={deterministic}",
        t_model.median, t_sim.median, r1.arrivals, r1.served, r1.batches
    );

    // machine-readable result (last line)
    println!(
        "{{\"bench\":\"traffic_sim\",\"model_ms\":{:.4},\
         \"sim_ms\":{:.4},\"arrivals\":{},\"served\":{},\
         \"batches\":{},\"cold_starts\":{},\
         \"hot_path_timeline_builds\":{hot_builds},\
         \"deterministic\":{deterministic}}}",
        t_model.median,
        t_sim.median,
        r1.arrivals,
        r1.served,
        r1.batches,
        r1.cold_starts
    );

    if check {
        assert_eq!(
            hot_builds, 0,
            "check failed: simulate() built {hot_builds} Timelines — \
             per-dispatch costs must come from the ServiceModel cache"
        );
        assert!(
            deterministic,
            "check failed: two runs of seed {} diverged:\n{j1}\n{j2}",
            profile.seed
        );
        assert!(
            identity_transparent,
            "check failed: identity fault injection perturbed the report"
        );
        assert_eq!(r1.arrivals, r1.served + r1.queued, "conservation");
        println!(
            "traffic_sim check OK (deterministic, 0 IR builds across \
             {} dispatched batches)",
            r1.batches
        );
    }
}
