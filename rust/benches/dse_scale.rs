//! Bench: the million-point DSE stack — contention-free cost-table
//! kernel + streaming Pareto front + dominance-aware pruning — against
//! the PR7 per-point engine (shared ctx, `Mutex<HashMap>` cost cache,
//! per-spec arch rebuild).
//!
//! Reports JSON on the last line so CI and scripts can consume it:
//!
//! ```json
//! {"bench":"dse_scale","huge_points":130536,...,"prune_identical":true}
//! ```
//!
//! Modes:
//!   (default)   measure + print JSON
//!   --check     CI mode: additionally assert the table kernel is
//!               >= 3x the PR7 path on the huge slice on machines with
//!               >= 4 cores (skips the assertion, not the run, on
//!               smaller machines)
//!   --threads N worker override (0 = all cores)
//!
//! Before timing anything the bench verifies (1) the table kernel is
//! bit-identical to the legacy per-point engine, and (2) the streamed
//! front — pruned and unpruned — is bit-identical to the post-hoc
//! `pareto::front` over the full sweep.  A violation fails the bench
//! outright.

use capstore::bench;
use capstore::capsnet::CapsNetConfig;
use capstore::dse::{pareto, Explorer, MultiSweep, SweepSpace};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let check = args.iter().any(|a| a == "--check");
    let threads: usize = args
        .iter()
        .position(|a| a == "--threads")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let mut ex = Explorer::new(CapsNetConfig::mnist()).with_threads(threads);
    ex.space = SweepSpace::huge();
    let huge_points = ex.space.num_points();
    assert!(
        huge_points >= 100_000,
        "huge slice shrank below the scale target: {huge_points}"
    );

    // ---- correctness gates (before any timing) ------------------------
    let legacy = ex.sweep_legacy().expect("legacy sweep");
    let table = ex.sweep().expect("table sweep");
    assert_eq!(legacy.len(), table.len());
    for (i, (l, t)) in legacy.iter().zip(&table).enumerate() {
        assert!(
            l.bit_eq(t),
            "table kernel diverged from the PR7 engine at point {i}: \
             {l:?} vs {t:?}"
        );
    }
    let post_hoc = pareto::front(&table);
    drop(legacy);

    let (front_off, stats_off) = ex.sweep_front(false).expect("front");
    let (front_on, stats_on) = ex.sweep_front(true).expect("pruned front");
    let same = |a: &[capstore::dse::DesignPoint],
                b: &[capstore::dse::DesignPoint]| {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.bit_eq(y))
    };
    assert!(
        same(&front_off, &post_hoc),
        "streamed front diverged from post-hoc pareto::front"
    );
    assert!(
        same(&front_on, &post_hoc),
        "pruned front diverged from the exhaustive front"
    );
    assert_eq!(stats_off.priced_points, stats_off.specs);
    assert_eq!(
        stats_on.pruned_points + stats_on.priced_points,
        stats_on.specs
    );
    let front_points = front_on.len();
    println!(
        "[dse_scale] determinism: {huge_points} table points bit-identical \
         to the PR7 engine; pruned + streamed fronts ({front_points} \
         points) match post-hoc pareto (pruned {} of {} points)",
        stats_on.pruned_points, stats_on.specs
    );

    // ---- timings ------------------------------------------------------
    let t_legacy = bench::bench(
        "dse_scale: PR7 per-point engine (huge slice)",
        0,
        3,
        || {
            std::hint::black_box(ex.sweep_legacy().unwrap());
        },
    );
    let t_table =
        bench::bench("dse_scale: table kernel (huge slice)", 0, 3, || {
            std::hint::black_box(ex.sweep().unwrap());
        });
    let slice_speedup = t_legacy.median / t_table.median.max(1e-9);

    // the grand multi-sweep: every model x every node x the huge space,
    // streamed — the full point set never materializes
    let ms = MultiSweep {
        threads,
        space: SweepSpace::huge(),
        ..MultiSweep::default()
    };
    let huge_grand_points = ms.num_points();
    assert!(
        huge_grand_points >= 1_000_000,
        "huge grand sweep shrank below a million points: \
         {huge_grand_points}"
    );
    let mut huge_front_points = 0usize;
    let t_grand = bench::bench(
        "dse_scale: huge grand sweep (streaming front, pruned)",
        0,
        1,
        || {
            let fronts = ms.run_front(true).unwrap();
            huge_front_points =
                fronts.iter().map(|mf| mf.front.len()).sum();
            std::hint::black_box(fronts);
        },
    );
    let grand_pps =
        huge_grand_points as f64 / (t_grand.median / 1.0e3).max(1e-12);

    println!(
        "\n[dse_scale] huge slice ({huge_points} points): PR7 engine \
         {:.2} ms -> table kernel {:.2} ms ({slice_speedup:.2}x) on \
         {cores} cores",
        t_legacy.median, t_table.median
    );
    println!(
        "[dse_scale] huge grand sweep: {huge_grand_points} points in \
         {:.2} ms ({grand_pps:.0} points/s), {huge_front_points} front \
         points survive",
        t_grand.median
    );

    // machine-readable result (last line)
    println!(
        "{{\"bench\":\"dse_scale\",\"huge_points\":{huge_points},\
         \"huge_grand_points\":{huge_grand_points},\"cores\":{cores},\
         \"threads\":{threads},\
         \"legacy_slice_ms\":{:.4},\"table_slice_ms\":{:.4},\
         \"slice_speedup\":{slice_speedup:.3},\"huge_grand_ms\":{:.4},\
         \"huge_points_per_sec\":{grand_pps:.0},\
         \"front_points\":{front_points},\
         \"huge_front_points\":{huge_front_points},\
         \"pruned_points\":{},\"priced_points\":{},\
         \"prune_identical\":true}}",
        t_legacy.median,
        t_table.median,
        t_grand.median,
        stats_on.pruned_points,
        stats_on.priced_points
    );

    if check {
        if cores >= 4 {
            assert!(
                slice_speedup >= 3.0,
                "check failed: table-kernel speedup {slice_speedup:.2}x \
                 < 3x over the PR7 engine on {cores} cores"
            );
            println!(
                "dse_scale check OK ({slice_speedup:.2}x >= 3x on \
                 {cores} cores)"
            );
        } else {
            println!(
                "dse_scale check SKIPPED (only {cores} cores; need >= 4 \
                 for the speedup assertion)"
            );
        }
    }
}
