//! Bench: cost of building the cycle-resolved Timeline IR, and a guard
//! that the DSE sweep hot path never builds it.
//!
//! The IR is constructed once per scenario evaluation (op intervals +
//! per-domain power-state segments + DMA placement); the DSE prices its
//! DMA axis with the O(ops) `timeline::dma_overhead_pj` scan instead.
//! `Timeline::build_count()` makes that contract observable: this bench
//! runs a full large-space sweep (DMA axis included) and asserts the
//! build counter did not move.
//!
//! Reports JSON on the last line:
//!
//! ```json
//! {"bench":"timeline_build","build_ms":...,"dse_timeline_builds":0,...}
//! ```
//!
//! Modes:
//!   (default)   measure + print JSON
//!   --check     CI mode: additionally assert dse_timeline_builds == 0

use capstore::analysis::breakdown::EnergyModel;
use capstore::bench;
use capstore::capsnet::CapsNetConfig;
use capstore::capstore::arch::{CapStoreArch, Organization};
use capstore::dse::{Explorer, SweepSpace};
use capstore::timeline::{Timeline, TimelinePolicy};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let check = args.iter().any(|a| a == "--check");

    let model = EnergyModel::new(CapsNetConfig::mnist());
    let ctx = model.context();
    let arch = CapStoreArch::build_default(
        Organization::Sep { gated: true },
        &model.req,
        &model.tech,
    )
    .expect("default PG-SEP builds");

    // ---- build cost: single inference and a pipelined batch ----------
    let t_one = bench::bench("timeline: build (PG-SEP, batch 1)", 2, 9, || {
        std::hint::black_box(Timeline::build(
            &ctx,
            &arch,
            &model.req,
            &TimelinePolicy::default(),
        ));
    });
    let t_batch =
        bench::bench("timeline: build (PG-SEP, batch 16)", 2, 9, || {
            std::hint::black_box(Timeline::build(
                &ctx,
                &arch,
                &model.req,
                &TimelinePolicy { batch: 16, ..TimelinePolicy::default() },
            ));
        });

    // ---- hot-path guard: a full sweep must not build timelines -------
    let mut ex = Explorer::new(CapsNetConfig::mnist());
    ex.space = SweepSpace::large(); // includes the DMA-overlap axis
    let points = ex.space.num_points();
    let before = Timeline::build_count();
    let t_sweep = bench::bench("timeline: dse sweep (no IR builds)", 1, 3, || {
        std::hint::black_box(ex.sweep().expect("sweep"));
    });
    let dse_builds = Timeline::build_count() - before;

    println!(
        "\n[timeline_build] build {:.3} ms (batch 16: {:.3} ms); sweep of \
         {points} points ran in {:.1} ms with {dse_builds} timeline builds",
        t_one.median, t_batch.median, t_sweep.median
    );

    // machine-readable result (last line)
    println!(
        "{{\"bench\":\"timeline_build\",\"build_ms\":{:.4},\
         \"batch16_build_ms\":{:.4},\"dse_points\":{points},\
         \"dse_sweep_ms\":{:.4},\"dse_timeline_builds\":{dse_builds}}}",
        t_one.median, t_batch.median, t_sweep.median
    );

    if check {
        assert_eq!(
            dse_builds, 0,
            "check failed: the DSE hot path built {dse_builds} timelines \
             — dma pricing must go through timeline::dma_overhead_pj"
        );
        println!(
            "timeline_build check OK (0 IR builds across {points} sweep \
             points)"
        );
    }
}
