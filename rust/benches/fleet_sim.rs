//! Bench: the fleet-scale serving simulator — throughput of the
//! sharded event loop, plus the contracts CI enforces in `--check`
//! mode:
//!
//! * **determinism** — two runs of the same seeded profile produce
//!   byte-identical `FleetReport` JSON for every dispatch policy;
//! * **hot path** — the fleet loop builds zero `Timeline` IRs: every
//!   per-batch cost comes from the per-instance `ServiceModel` tables
//!   precomputed before the loop starts;
//! * **conservation** — `arrivals == served + queued + shed` at the
//!   horizon, saturated or not.
//!
//! Reports JSON on the last line:
//!
//! ```json
//! {"bench":"fleet_sim","sim_ms":...,"hot_path_timeline_builds":0,...}
//! ```

use std::time::Duration;

use capstore::bench;
use capstore::coordinator::BatchPolicy;
use capstore::fleet::{simulate_fleet, DispatchPolicy, FleetSpec};
use capstore::scenario::{Evaluator, Scenario};
use capstore::timeline::Timeline;
use capstore::traffic::{ArrivalPattern, ServiceModel, TrafficProfile};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let check = args.iter().any(|a| a == "--check");

    let ev = Evaluator::new();
    let sc = Scenario::default();
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
    };
    let svc = ServiceModel::new(&ev, &sc, policy.max_batch).unwrap();
    let models = vec![svc; 4];

    let profile = TrafficProfile {
        pattern: ArrivalPattern::Poisson,
        rate_per_sec: 3000.0,
        seed: 7,
        duration_secs: 0.25,
        slo_ms: 50.0,
    };
    let spec = FleetSpec {
        instances: 4,
        policy: DispatchPolicy::Packing,
        elastic: true,
        scale_up_depth: 4,
        min_active: 1,
    };

    // ---- contracts ---------------------------------------------------
    let before = Timeline::build_count();
    let r1 = simulate_fleet(&models, &profile, &policy, &spec).unwrap();
    let hot_builds = Timeline::build_count() - before;
    let r2 = simulate_fleet(&models, &profile, &policy, &spec).unwrap();
    let j1 = r1.to_json().render();
    let deterministic = j1 == r2.to_json().render();
    let mut all_policies_deterministic = true;
    for dispatch in DispatchPolicy::all() {
        let s = FleetSpec { policy: dispatch, ..spec.clone() };
        let a = simulate_fleet(&models, &profile, &policy, &s)
            .unwrap()
            .to_json()
            .render();
        let b = simulate_fleet(&models, &profile, &policy, &s)
            .unwrap()
            .to_json()
            .render();
        all_policies_deterministic &= a == b;
    }
    let conserves = r1.conserves();

    // ---- sharded event-loop throughput ------------------------------
    let t_sim = bench::bench(
        "fleet: simulate (poisson 3000/s x 0.25s, 4 inst, packing)",
        2,
        9,
        || {
            std::hint::black_box(
                simulate_fleet(&models, &profile, &policy, &spec)
                    .unwrap(),
            );
        },
    );

    println!(
        "\n[fleet_sim] sim {:.3} ms for {} arrivals ({} served, {} \
         batches, {} gated-off instances, peak {} active); \
         {hot_builds} timeline builds in the fleet loop; \
         deterministic={deterministic}",
        t_sim.median,
        r1.arrivals,
        r1.served,
        r1.batches,
        r1.gated_off_instances,
        r1.peak_active,
    );

    // machine-readable result (last line)
    println!(
        "{{\"bench\":\"fleet_sim\",\"sim_ms\":{:.4},\"arrivals\":{},\
         \"served\":{},\"batches\":{},\"gated_off_instances\":{},\
         \"scale_ups\":{},\"hot_path_timeline_builds\":{hot_builds},\
         \"deterministic\":{deterministic}}}",
        t_sim.median,
        r1.arrivals,
        r1.served,
        r1.batches,
        r1.gated_off_instances,
        r1.scale_ups,
    );

    if check {
        assert_eq!(
            hot_builds, 0,
            "check failed: the fleet loop built {hot_builds} Timelines \
             — per-dispatch costs must come from the ServiceModel \
             tables"
        );
        assert!(
            deterministic && all_policies_deterministic,
            "check failed: two runs of seed {} diverged",
            profile.seed
        );
        assert!(
            conserves,
            "check failed: fleet conservation broke: {} != {} + {} + {}",
            r1.arrivals, r1.served, r1.queued, r1.shed
        );
        println!(
            "fleet_sim check OK (deterministic across every policy, \
             0 IR builds across {} dispatched batches)",
            r1.batches
        );
    }
}
