//! Bench: regenerate the paper's Fig 11 — energy and area breakdown of
//! the COMPLETE accelerator with the selected PG-SEP memory — and check
//! the paper's §5.2 headline reductions:
//!   * total energy −78% vs version (a) (all on-chip)
//!   * on-chip energy −86% vs version (b) (SMP hierarchy)   [ours ~−69%]
//!   * total energy −46% vs version (b)
//!   * accelerator contributes only a few % of energy and area

use capstore::analysis::breakdown::EnergyModel;
use capstore::bench;
use capstore::capsnet::CapsNetConfig;
use capstore::capstore::arch::{CapStoreArch, Organization};
use capstore::report::paper::PaperReference;
use capstore::util::units::fmt_energy_uj;

fn main() {
    let model = EnergyModel::new(CapsNetConfig::mnist());
    let smp = CapStoreArch::build_default(
        Organization::Smp { gated: false },
        &model.req,
        &model.tech,
    )
    .unwrap();
    let pg_sep = CapStoreArch::build_default(
        Organization::Sep { gated: true },
        &model.req,
        &model.tech,
    )
    .unwrap();

    bench::bench("fig11: three whole-system evaluations", 2, 10, || {
        let a = model.all_onchip_baseline().unwrap();
        let b = model.system_energy(&smp);
        let c = model.system_energy(&pg_sep);
        std::hint::black_box((a.total_pj(), b.total_pj(), c.total_pj()));
    });

    let a = model.all_onchip_baseline().unwrap();
    let b = model.system_energy(&smp);
    let c = model.system_energy(&pg_sep);

    println!("\n== Fig 11a — energy breakdown (PG-SEP complete system) ==");
    let tot = c.total_pj();
    println!(
        "accelerator {:>10} ({:4.1}%)   on-chip {:>10} ({:4.1}%)   off-chip {:>10} ({:4.1}%)",
        fmt_energy_uj(c.accel_pj),
        100.0 * c.accel_pj / tot,
        fmt_energy_uj(c.onchip_pj),
        100.0 * c.onchip_pj / tot,
        fmt_energy_uj(c.offchip_pj),
        100.0 * c.offchip_pj / tot,
    );

    println!("\n== Fig 11b — area breakdown (on-chip, mm²) ==");
    let accel_area = model.accel.area_mm2();
    let mem_area = pg_sep.area_mm2();
    println!(
        "accelerator {accel_area:.2}   PG-SEP memory {mem_area:.2}   \
         (all-on-chip [11] memory would be {:.2})",
        model.all_onchip_area_mm2().unwrap()
    );

    let vs_a = 1.0 - c.total_pj() / a.total_pj();
    let vs_b_onchip = 1.0 - c.onchip_pj / b.onchip_pj;
    let vs_b_total = 1.0 - c.total_pj() / b.total_pj();
    println!();
    println!(
        "{}",
        PaperReference::delta_line(
            "total vs (a)",
            vs_a,
            PaperReference::PG_SEP_TOTAL_VS_A
        )
    );
    println!(
        "{}",
        PaperReference::delta_line(
            "on-chip vs (b)",
            vs_b_onchip,
            PaperReference::PG_SEP_ONCHIP_SAVING
        )
    );
    println!(
        "{}",
        PaperReference::delta_line(
            "total vs (b)",
            vs_b_total,
            PaperReference::PG_SEP_TOTAL_VS_B
        )
    );

    assert!(vs_a > 0.70 && vs_a < 0.92, "total vs (a): {vs_a}");
    assert!(vs_b_onchip > 0.60, "on-chip vs (b): {vs_b_onchip}");
    assert!(vs_b_total > 0.30 && vs_b_total < 0.60, "total vs (b): {vs_b_total}");
    // paper: accelerator is 4-5% of total
    assert!(c.accel_pj / tot < 0.25, "accel share {}", c.accel_pj / tot);
    println!("fig11_complete OK");
}
