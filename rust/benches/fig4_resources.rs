//! Bench: regenerate the paper's Fig 4 (a-e) + Eqs 1/2 — the per-op
//! memory requirement, cycle, access, and off-chip analysis — and time
//! the analysis pipeline itself.
//!
//! Shape checks asserted here (the paper's claims):
//!   * PrimaryCaps sets the overall on-chip worst case (Fig 4a)
//!   * routing ops have zero off-chip traffic (Eq 1/2)
//!   * weight memory idle during routing (Fig 4c)

use capstore::accel::systolic::SystolicSim;
use capstore::analysis::offchip::OffChipTraffic;
use capstore::analysis::requirements::RequirementsAnalysis;
use capstore::bench;
use capstore::capsnet::{CapsNetConfig, OpKind, Operation};
use capstore::report::table::Table;
use capstore::util::units::{fmt_bytes, fmt_si};

fn main() {
    let cfg = CapsNetConfig::mnist();
    let sim = SystolicSim::default();

    // ---- timing: the full §3 analysis pipeline -------------------------
    bench::bench("fig4: requirements+profiles+offchip", 3, 20, || {
        let req = RequirementsAnalysis::analyze(&cfg, &sim.array);
        let profiles = sim.profile_all(&cfg);
        let off = OffChipTraffic::from_profiles(&cfg, &profiles);
        std::hint::black_box((req.max_total(), off.len()));
    });

    // ---- Fig 4a/4c ------------------------------------------------------
    let req = RequirementsAnalysis::analyze(&cfg, &sim.array);
    let cap = req.max_total();
    let mut t = Table::new(
        "Fig 4a/4c — per-op requirements (bytes)",
        &["op", "data", "weight", "accum", "total", "util%"],
    );
    for o in &req.per_op {
        t.row(vec![
            o.kind.label().into(),
            o.req.data.to_string(),
            o.req.weight.to_string(),
            o.req.accum.to_string(),
            o.req.total().to_string(),
            format!("{:.1}", 100.0 * o.req.total() as f64 / cap as f64),
        ]);
    }
    t.print();
    println!("worst case: {}", fmt_bytes(cap));

    // paper claim: PC is the worst case
    assert_eq!(req.get(OpKind::PrimaryCaps).total(), cap, "PC must set the max");

    // ---- Fig 4b ----------------------------------------------------------
    let mut t = Table::new("Fig 4b — cycles", &["op", "cycles"]);
    for op in Operation::all_kinds(&cfg) {
        t.row(vec![op.kind.label().into(), fmt_si(sim.profile(&op).cycles)]);
    }
    t.print();

    // ---- Fig 4d/4e -------------------------------------------------------
    let mut t = Table::new(
        "Fig 4d/4e — accesses",
        &["op", "data R", "data W", "wt R", "wt W", "acc R", "acc W"],
    );
    for op in Operation::all_kinds(&cfg) {
        let p = sim.profile(&op);
        if matches!(op.kind, OpKind::SumSquash | OpKind::UpdateSum) {
            assert_eq!(p.weight_reads + p.weight_writes, 0);
        }
        t.row(vec![
            op.kind.label().into(),
            fmt_si(p.data_reads),
            fmt_si(p.data_writes),
            fmt_si(p.weight_reads),
            fmt_si(p.weight_writes),
            fmt_si(p.accum_reads),
            fmt_si(p.accum_writes),
        ]);
    }
    t.print();

    // ---- Eq 1/2 ----------------------------------------------------------
    let mut t =
        Table::new("Eq (1)/(2) — off-chip accesses", &["op", "reads", "writes"]);
    for tr in OffChipTraffic::analyze(&cfg, &sim) {
        if matches!(tr.kind, OpKind::SumSquash | OpKind::UpdateSum) {
            assert_eq!((tr.reads, tr.writes), (0, 0));
        }
        t.row(vec![
            tr.kind.label().into(),
            fmt_si(tr.reads),
            fmt_si(tr.writes),
        ]);
    }
    t.print();
    println!("fig4_resources OK");
}
