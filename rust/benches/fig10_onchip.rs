//! Bench: regenerate the paper's Fig 10 (a-d) — area breakdown per
//! memory component, energy breakdown per component, dynamic-vs-static
//! split, and energy per CapsuleNet operation, for all six CapStore
//! organizations.
//!
//! Shape checks (§5.1):
//!   * SMP→SEP cuts dynamic energy; SEP→PG-SEP cuts static energy
//!   * wakeup energy is negligible
//!   * PC consumes the largest memory energy of any operation

use capstore::analysis::breakdown::EnergyModel;
use capstore::bench;
use capstore::capsnet::{CapsNetConfig, OpKind, OP_SEQUENCE};
use capstore::capstore::arch::CapStoreArch;
use capstore::report::table::Table;
use capstore::util::units::fmt_energy_uj;

fn main() {
    let model = EnergyModel::new(CapsNetConfig::mnist());
    let archs = CapStoreArch::all_default(&model.req, &model.tech).unwrap();

    bench::bench("fig10: per-macro + per-op breakdowns", 2, 10, || {
        for a in &archs {
            std::hint::black_box(model.evaluate_arch(a).onchip_pj);
        }
    });

    // ---- Fig 10a: area breakdown ----------------------------------------
    let mut t = Table::new(
        "Fig 10a — area per memory component (mm2)",
        &["org", "macro", "array", "power-gating", "total"],
    );
    for a in &archs {
        for m in &a.macros {
            t.row(vec![
                a.organization.label().into(),
                m.role.label().into(),
                format!("{:.3}", m.costs.area_mm2),
                format!("{:.3}", m.pg_area_mm2),
                format!("{:.3}", m.area_mm2()),
            ]);
        }
    }
    t.print();
    println!();

    // ---- Fig 10b/10c: energy per component, dynamic vs static -----------
    let mut t = Table::new(
        "Fig 10b/10c — energy per component (per inference)",
        &["org", "macro", "dynamic", "static", "wakeup", "total"],
    );
    let mut fig10c: Vec<(String, f64, f64, f64)> = Vec::new();
    for a in &archs {
        let e = model.evaluate_arch(a);
        let mut dyn_sum = 0.0;
        let mut stat_sum = 0.0;
        let mut wake_sum = 0.0;
        for (m, b) in a.macros.iter().zip(&e.per_macro) {
            dyn_sum += b.dynamic_pj;
            stat_sum += b.static_pj;
            wake_sum += b.wakeup_pj;
            t.row(vec![
                a.organization.label().into(),
                m.role.label().into(),
                fmt_energy_uj(b.dynamic_pj),
                fmt_energy_uj(b.static_pj),
                fmt_energy_uj(b.wakeup_pj),
                fmt_energy_uj(b.total_pj()),
            ]);
        }
        fig10c.push((
            a.organization.label().to_string(),
            dyn_sum,
            stat_sum,
            wake_sum,
        ));
    }
    t.print();
    println!();

    let mut t = Table::new(
        "Fig 10c — dynamic vs static per organization",
        &["org", "dynamic", "static", "wakeup"],
    );
    for (l, d, s, w) in &fig10c {
        t.row(vec![
            l.clone(),
            fmt_energy_uj(*d),
            fmt_energy_uj(*s),
            fmt_energy_uj(*w),
        ]);
    }
    t.print();
    println!();

    // ---- Fig 10d: energy per operation -----------------------------------
    let mut t = Table::new(
        "Fig 10d — on-chip energy per operation",
        &["org", "C1", "PC", "CC-FC", "Sum+Squash", "Update+Sum"],
    );
    for a in &archs {
        let e = model.evaluate_arch(a);
        let sum_for = |k: OpKind| -> f64 {
            e.per_op_pj.iter().filter(|(x, _)| *x == k).map(|(_, v)| v).sum()
        };
        let cells: Vec<String> = OP_SEQUENCE
            .iter()
            .map(|k| fmt_energy_uj(sum_for(*k)))
            .collect();
        let mut row = vec![a.organization.label().to_string()];
        row.extend(cells);
        t.row(row);
        // paper: PC dominates the per-op split in every organization
        let pc = sum_for(OpKind::PrimaryCaps);
        for k in OP_SEQUENCE {
            assert!(pc >= sum_for(k) * 0.99, "{}: PC not max", a.organization.label());
        }
    }
    t.print();

    // ---- shape assertions on Fig 10c --------------------------------------
    let find = |l: &str| fig10c.iter().find(|x| x.0 == l).unwrap();
    assert!(find("SEP").1 < 0.75 * find("SMP").1, "SMP->SEP dynamic cut");
    assert!(find("PG-SEP").2 < 0.45 * find("SEP").2, "SEP->PG-SEP static cut");
    let pg_sep = find("PG-SEP");
    assert!(
        pg_sep.3 < 0.02 * (pg_sep.1 + pg_sep.2),
        "wakeup must be negligible"
    );
    println!("fig10_onchip OK");
}
