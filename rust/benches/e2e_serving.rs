//! Bench: end-to-end serving — batched synthetic-digit inference through
//! the PJRT runtime with the PG-SEP energy accountant attached.  Reports
//! latency/throughput (real) and µJ/inference (simulated memory model).
//!
//! This is the "ours" row of the experiment index: the paper has no
//! serving experiment, but the reproduction must prove all three layers
//! compose on a real workload.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use capstore::coordinator::batcher::BatchPolicy;
use capstore::coordinator::server::{InferenceServer, ServerConfig};
use capstore::scenario::Scenario;
use capstore::testing::SplitMix64;
use capstore::util::units::fmt_energy_uj;

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("e2e_serving SKIPPED (run `make artifacts` first)");
        return;
    }

    // small config keeps the bench tight; the mnist config runs the same
    // path (see examples/serve_inference.rs for the full-size run)
    for (model, requests, clients) in [("small", 64usize, 4usize), ("mnist", 16, 2)]
    {
        let server = InferenceServer::start(
            dir.clone(),
            model.into(),
            ServerConfig {
                queue_depth: 64,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(2),
                },
                // PG-SEP at the paper's defaults (Scenario::default)
                scenario: Scenario::default(),
            },
        )
        .expect("server start");

        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..clients {
            let h = server.handle();
            let n = requests / clients;
            joins.push(std::thread::spawn(move || {
                let mut rng = SplitMix64::new(42 + c as u64);
                for _ in 0..n {
                    let img: Vec<f32> =
                        (0..784).map(|_| rng.f64() as f32).collect();
                    h.infer(img).expect("infer");
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = server.shutdown();

        let lat = m.latency.summary().expect("latencies recorded");
        println!(
            "[bench] e2e[{model}]: {} reqs in {wall:.2}s -> {:.1} inf/s; \
             latency median {:.2} ms p95 {:.2} ms; occupancy {:.2}; \
             sim energy {} total, {:.2} µJ/inf (PG-SEP)",
            m.requests,
            m.requests as f64 / wall,
            lat.median,
            lat.p95,
            m.mean_occupancy(),
            fmt_energy_uj(m.sim_energy_pj),
            m.energy_uj_per_inference(),
        );
        assert_eq!(m.requests as usize, (requests / clients) * clients);
        assert!(m.energy_uj_per_inference() > 0.0);
    }
    println!("e2e_serving OK");
}
