//! Bench: regenerate the paper's Fig 5 — energy breakdown of (a) the
//! all-on-chip CapsAcc baseline vs (b) the on-chip/off-chip hierarchy —
//! and check the two headline claims of §3.2/§3.3:
//!   * the hierarchy saves about two thirds of total energy (paper: 66%)
//!   * memory dominates total energy (paper: 96%)

use capstore::analysis::breakdown::EnergyModel;
use capstore::bench;
use capstore::capsnet::CapsNetConfig;
use capstore::capstore::arch::{CapStoreArch, Organization};
use capstore::report::paper::PaperReference;
use capstore::util::units::fmt_energy_uj;

fn main() {
    let model = EnergyModel::new(CapsNetConfig::mnist());
    let smp = CapStoreArch::build_default(
        Organization::Smp { gated: false },
        &model.req,
        &model.tech,
    )
    .unwrap();

    bench::bench("fig5: both system evaluations", 3, 20, || {
        let a = model.all_onchip_baseline().unwrap();
        let b = model.system_energy(&smp);
        std::hint::black_box((a.total_pj(), b.total_pj()));
    });

    let a = model.all_onchip_baseline().unwrap();
    let b = model.system_energy(&smp);

    println!("\n== Fig 5 — energy breakdown per inference ==");
    for sys in [&a, &b] {
        let tot = sys.total_pj();
        println!(
            "{:18} accel {:>10} ({:4.1}%)  onchip {:>10} ({:4.1}%)  offchip {:>10} ({:4.1}%)  total {}",
            sys.label,
            fmt_energy_uj(sys.accel_pj),
            100.0 * sys.accel_pj / tot,
            fmt_energy_uj(sys.onchip_pj),
            100.0 * sys.onchip_pj / tot,
            fmt_energy_uj(sys.offchip_pj),
            100.0 * sys.offchip_pj / tot,
            fmt_energy_uj(tot),
        );
    }

    let saving = 1.0 - b.total_pj() / a.total_pj();
    println!(
        "\n{}",
        PaperReference::delta_line(
            "hierarchy saving",
            saving,
            PaperReference::HIERARCHY_SAVING
        )
    );
    println!(
        "{}",
        PaperReference::delta_line(
            "memory share (a)",
            a.memory_share(),
            PaperReference::MEMORY_SHARE
        )
    );

    assert!(saving > 0.45 && saving < 0.85, "saving {saving}");
    assert!(a.memory_share() > 0.85 && b.memory_share() > 0.80);
    println!("fig5_breakdown OK");
}
