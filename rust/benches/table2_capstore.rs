//! Bench: regenerate the paper's Table 1 (organization geometries) and
//! Table 2 (area + energy per organization), printing measured-vs-paper
//! energy ratios normalized to SMP.
//!
//! Shape checks (Table 2 / §5.1):
//!   * SEP beats SMP on energy; PG-SEP is the overall winner
//!   * SEP has more capacity but less area than SMP (single- vs 3-port)
//!   * every PG- variant adds area (sleep transistors) and saves energy

use capstore::analysis::breakdown::EnergyModel;
use capstore::bench;
use capstore::capsnet::CapsNetConfig;
use capstore::capstore::arch::CapStoreArch;
use capstore::report::paper::PaperReference;
use capstore::report::table::Table;
use capstore::util::units::{fmt_bytes, fmt_energy_uj};

fn main() {
    let model = EnergyModel::new(CapsNetConfig::mnist());
    let paper = PaperReference::new();

    bench::bench("table2: evaluate all six organizations", 2, 10, || {
        std::hint::black_box(model.evaluate_all().unwrap().len());
    });

    let archs = CapStoreArch::all_default(&model.req, &model.tech).unwrap();
    let evals = model.evaluate_all().unwrap();

    let mut t1 = Table::new(
        "Table 1 — geometry",
        &["org", "macro", "size", "banks", "sectors", "ports"],
    );
    for arch in &archs {
        for m in &arch.macros {
            t1.row(vec![
                arch.organization.label().into(),
                m.role.label().into(),
                m.sram.size_bytes.to_string(),
                m.sram.banks.to_string(),
                m.sram.sectors.to_string(),
                m.sram.ports.to_string(),
            ]);
        }
    }
    t1.print();
    println!();

    let smp = evals
        .iter()
        .find(|e| e.organization.label() == "SMP")
        .unwrap()
        .onchip_pj;
    let mut t2 = Table::new(
        "Table 2 — area + energy",
        &["org", "capacity", "area mm2", "energy/inf", "vs SMP", "paper vs SMP"],
    );
    for e in &evals {
        let ours = e.onchip_pj / smp;
        let theirs = paper
            .energy_vs_smp(e.organization.label())
            .map(|r| format!("{r:.3}"))
            .unwrap_or_default();
        t2.row(vec![
            e.organization.label().into(),
            fmt_bytes(e.capacity_bytes),
            format!("{:.3}", e.area_mm2),
            fmt_energy_uj(e.onchip_pj),
            format!("{ours:.3}"),
            theirs,
        ]);
    }
    t2.print();

    // ---- shape assertions ------------------------------------------------
    let get = |l: &str| evals.iter().find(|e| e.organization.label() == l).unwrap();
    assert!(get("SEP").onchip_pj < get("SMP").onchip_pj);
    let winner = evals
        .iter()
        .min_by(|a, b| a.onchip_pj.partial_cmp(&b.onchip_pj).unwrap())
        .unwrap();
    assert_eq!(winner.organization.label(), "PG-SEP", "paper §5.2 winner");
    let sep_arch = &archs[2];
    let smp_arch = &archs[0];
    assert!(sep_arch.capacity() >= smp_arch.capacity());
    assert!(sep_arch.area_mm2() < smp_arch.area_mm2());
    for pair in archs.chunks(2) {
        assert!(pair[1].area_mm2() > pair[0].area_mm2(), "PG adds area");
    }
    for (plain, gated) in [("SMP", "PG-SMP"), ("SEP", "PG-SEP"), ("HY", "PG-HY")] {
        assert!(get(gated).onchip_pj < get(plain).onchip_pj, "{gated}");
    }
    println!("table2_capstore OK");
}
